//! Perf-snapshot comparison: ratio-based, host-independent gating over
//! `BENCH_*.json` files written by `cargo bench --bench hotpath -- --json`.
//!
//! Raw millisecond medians are machine-dependent, so `suite --compare`
//! gates **only the speedup ratios** (metric names containing
//! `"speedup"`): a tiling or threading regression shows up as a ratio
//! drop on any host, while a slower CI machine shifts every absolute
//! number uniformly and leaves the ratios alone. A metric must drop more
//! than the tolerance (default 10%) below its baseline ratio to count as
//! a regression; a baseline speedup metric missing from the current
//! snapshot is always a regression (deleting the measurement must not
//! silence the gate).

use crate::util::json::Json;
use std::path::Path;

/// Allowed relative drop in a speedup ratio before it gates
/// (`current < baseline * (1 - tolerance)` regresses).
pub const DEFAULT_TOLERANCE: f64 = 0.10;

/// A parsed `BENCH_*.json` snapshot: `group -> metric -> value`.
pub struct BenchSnapshot {
    pub bench: String,
    pub mode: String,
    pub groups: Vec<(String, Vec<(String, f64)>)>,
}

impl BenchSnapshot {
    /// Parse a snapshot object (detected by its `bench` + `groups` keys).
    pub fn from_json(j: &Json) -> Option<BenchSnapshot> {
        let bench = j.get("bench")?.as_str()?.to_string();
        let Json::Obj(groups_obj) = j.get("groups")? else { return None };
        let mut groups = Vec::new();
        for (gname, g) in groups_obj {
            let Json::Obj(metrics_obj) = g else { return None };
            let mut metrics = Vec::new();
            for (mname, v) in metrics_obj {
                metrics.push((mname.clone(), v.as_f64()?));
            }
            groups.push((gname.clone(), metrics));
        }
        Some(BenchSnapshot {
            bench,
            mode: j.get("mode").and_then(Json::as_str).unwrap_or("").to_string(),
            groups,
        })
    }

    pub fn load(path: &Path) -> Result<BenchSnapshot, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        BenchSnapshot::from_json(&j)
            .ok_or_else(|| format!("{}: not a bench snapshot (no 'bench'/'groups')", path.display()))
    }

    pub fn metric(&self, group: &str, name: &str) -> Option<f64> {
        let (_, metrics) = self.groups.iter().find(|(g, _)| g == group)?;
        metrics.iter().find(|(m, _)| m == name).map(|(_, v)| *v)
    }

    /// Every `(group, metric, value)` whose metric name names a speedup —
    /// the host-independent subset the gate compares.
    pub fn speedups(&self) -> Vec<(&str, &str, f64)> {
        let mut out = Vec::new();
        for (group, metrics) in &self.groups {
            for (name, value) in metrics {
                if name.contains("speedup") {
                    out.push((group.as_str(), name.as_str(), *value));
                }
            }
        }
        out
    }
}

/// One compared speedup metric.
pub struct BenchRow {
    pub group: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
}

impl BenchRow {
    pub fn regressed(&self, tolerance: f64) -> bool {
        self.current < self.baseline * (1.0 - tolerance)
    }
}

/// The comparison outcome: per-metric rows plus baseline speedups the
/// current snapshot no longer reports.
pub struct BenchDelta {
    pub tolerance: f64,
    pub rows: Vec<BenchRow>,
    /// `group/metric` names present in the baseline but absent now.
    pub missing: Vec<String>,
}

/// Compare every baseline speedup ratio against the current snapshot.
/// New metrics (in current, not baseline) pass silently — they have no
/// reference yet.
pub fn compare_bench(baseline: &BenchSnapshot, current: &BenchSnapshot, tolerance: f64) -> BenchDelta {
    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for (group, metric, base) in baseline.speedups() {
        match current.metric(group, metric) {
            Some(cur) => rows.push(BenchRow {
                group: group.to_string(),
                metric: metric.to_string(),
                baseline: base,
                current: cur,
            }),
            None => missing.push(format!("{group}/{metric}")),
        }
    }
    BenchDelta { tolerance, rows, missing }
}

impl BenchDelta {
    pub fn regressed(&self) -> bool {
        !self.missing.is_empty() || self.rows.iter().any(|r| r.regressed(self.tolerance))
    }

    /// Aligned-text report in the suite-table style.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "Bench snapshot comparison (speedup ratios only, tolerance {:.0}%).\n",
            self.tolerance * 100.0
        ));
        s.push_str(&format!(
            "{:<14} {:<28} {:>10} {:>10}  {}\n",
            "Group", "Metric", "Baseline", "Current", "Status"
        ));
        for r in &self.rows {
            s.push_str(&format!(
                "{:<14} {:<28} {:>9.2}x {:>9.2}x  {}\n",
                r.group,
                r.metric,
                r.baseline,
                r.current,
                if r.regressed(self.tolerance) { "REGRESSED" } else { "ok" }
            ));
        }
        for m in &self.missing {
            s.push_str(&format!("missing from current snapshot: {m}  REGRESSED\n"));
        }
        s.push_str(if self.regressed() {
            "RESULT: regression detected\n"
        } else {
            "RESULT: no regression\n"
        });
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pairs: &[(&str, &str, f64)]) -> BenchSnapshot {
        let mut s = BenchSnapshot { bench: "hotpath".into(), mode: "full".into(), groups: Vec::new() };
        for (g, m, v) in pairs {
            match s.groups.iter_mut().find(|(name, _)| name == g) {
                Some((_, metrics)) => metrics.push((m.to_string(), *v)),
                None => s.groups.push((g.to_string(), vec![(m.to_string(), *v)])),
            }
        }
        s
    }

    #[test]
    fn only_speedup_metrics_are_gated() {
        let baseline = snap(&[
            ("matmul", "512 speedup", 4.0),
            ("matmul", "512 tiled ms", 40.0),
        ]);
        // ms blew up 10x (slow host) but the ratio held: no regression
        let current = snap(&[
            ("matmul", "512 speedup", 3.9),
            ("matmul", "512 tiled ms", 400.0),
        ]);
        let delta = compare_bench(&baseline, &current, DEFAULT_TOLERANCE);
        assert!(!delta.regressed(), "{}", delta.render());
        assert_eq!(delta.rows.len(), 1, "only the speedup row is compared");
    }

    #[test]
    fn a_ratio_drop_beyond_tolerance_regresses() {
        let baseline = snap(&[("serve", "warm speedup", 10.0)]);
        let ok = snap(&[("serve", "warm speedup", 9.1)]);
        assert!(!compare_bench(&baseline, &ok, DEFAULT_TOLERANCE).regressed());
        let bad = snap(&[("serve", "warm speedup", 8.9)]);
        let delta = compare_bench(&baseline, &bad, DEFAULT_TOLERANCE);
        assert!(delta.regressed());
        assert!(delta.render().contains("REGRESSED"), "{}", delta.render());
    }

    #[test]
    fn a_missing_baseline_speedup_regresses() {
        let baseline = snap(&[("matmul", "512 speedup", 4.0)]);
        let current = snap(&[("matmul", "512 tiled ms", 40.0)]);
        let delta = compare_bench(&baseline, &current, DEFAULT_TOLERANCE);
        assert!(delta.regressed());
        assert_eq!(delta.missing, vec!["matmul/512 speedup".to_string()]);
    }

    #[test]
    fn new_current_metrics_pass_without_a_reference() {
        let baseline = snap(&[("matmul", "512 speedup", 4.0)]);
        let current =
            snap(&[("matmul", "512 speedup", 4.2), ("serve", "warm speedup", 11.0)]);
        assert!(!compare_bench(&baseline, &current, DEFAULT_TOLERANCE).regressed());
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let text = r#"{"bench":"hotpath","version":1,"mode":"quick",
            "groups":{"matmul":{"512 speedup":4.6,"512 tiled ms":46.8}}}"#;
        let j = Json::parse(text).unwrap();
        let s = BenchSnapshot::from_json(&j).unwrap();
        assert_eq!(s.bench, "hotpath");
        assert_eq!(s.mode, "quick");
        assert_eq!(s.metric("matmul", "512 speedup"), Some(4.6));
        assert_eq!(s.speedups(), vec![("matmul", "512 speedup", 4.6)]);
        // a suite baseline is not a bench snapshot
        let suite = Json::parse(r#"{"tasks":[]}"#).unwrap();
        assert!(BenchSnapshot::from_json(&suite).is_none());
    }
}
