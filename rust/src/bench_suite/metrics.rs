//! Benchmark metrics: Comp@1, Pass@1, Fast₀.₂/₀.₈/₁.₀ (paper §5.1) and the
//! Table 1 / Table 2 renderers.
//!
//! Fastₓ counts a kernel when `eager_cycles / generated_cycles >= x`, i.e.
//! the generated kernel reaches at least x× the eager baseline's speed.
//! Percentages are over *all* kernels in a category (incorrect kernels can
//! never be fast), matching the paper's arithmetic (e.g. Loss Fast = 85.7%
//! = 6/7 with one incorrect kernel).

use super::spec::Category;
use crate::coordinator::stage::{Diagnostic, StageReport};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Outcome of the L2↔L3 golden cross-check that `run_suite` performs per
/// task when `SuiteConfig::golden` is set: the JAX golden oracle (HLO
/// executed by the compiled plan) compared against the Rust reference.
#[derive(Clone, Debug)]
pub struct GoldenStatus {
    /// An artifact existed and was executed (false = vacuous pass).
    pub checked: bool,
    /// Oracle and Rust reference agreed within tolerance.
    pub ok: bool,
    pub detail: String,
}

/// Outcome of one task through the full pipeline.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: String,
    pub category: Category,
    /// Name of the execution backend that produced this result (the
    /// default suite runs on `"ascend-sim"`).
    pub backend: String,
    pub compiled: bool,
    pub correct: bool,
    /// Simulated cycles of the generated kernel (if it ran).
    pub generated_cycles: Option<f64>,
    /// Simulated cycles of the eager baseline.
    pub eager_cycles: f64,
    /// Structured failure: the diagnostic of the stage that stopped the
    /// pipeline (None when the task verified end to end).
    pub failure: Option<Diagnostic>,
    /// Number of repair-feedback rounds consumed across passes.
    pub repair_rounds: usize,
    /// Error-severity findings from the static analyzer (`ASCAN###`
    /// codes; 0 for tasks that never reached the analyze stage).
    pub analysis_errors: usize,
    /// Warning-severity analyzer findings.
    pub analysis_warnings: usize,
    /// Wall-clock seconds the pipeline spent on this task.
    pub pipeline_secs: f64,
    /// Per-stage wall time + outcome, in execution order (the session's
    /// stage reports; empty only for hand-built results).
    pub stage_timings: Vec<StageReport>,
    /// Golden cross-check outcome (None when the suite ran without it).
    /// When the check ran over several seeds this is the aggregate;
    /// per-seed outcomes are in [`TaskResult::golden_seeds`].
    pub golden: Option<GoldenStatus>,
    /// Per-seed golden cross-check outcomes, in seed order (empty when
    /// the suite ran without `--golden`).
    pub golden_seeds: Vec<GoldenStatus>,
}

impl TaskResult {
    /// eager/generated speed ratio (>= 1.0 means generated wins).
    pub fn speedup(&self) -> Option<f64> {
        match (self.correct, self.generated_cycles) {
            (true, Some(g)) if g > 0.0 => Some(self.eager_cycles / g),
            _ => None,
        }
    }

    pub fn fast_at(&self, x: f64) -> bool {
        self.speedup().map(|s| s >= x).unwrap_or(false)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str())
            .set("category", self.category.name())
            .set("backend", self.backend.as_str())
            .set("compiled", self.compiled)
            .set("correct", self.correct)
            .set("eager_cycles", self.eager_cycles)
            .set("repair_rounds", self.repair_rounds)
            .set("analysis_errors", self.analysis_errors)
            .set("analysis_warnings", self.analysis_warnings)
            .set("pipeline_secs", self.pipeline_secs);
        match self.generated_cycles {
            Some(g) => j.set("generated_cycles", g),
            None => j.set("generated_cycles", Json::Null),
        };
        match self.speedup() {
            Some(s) => j.set("speedup", s),
            None => j.set("speedup", Json::Null),
        };
        if let Some(f) = &self.failure {
            j.set("failure", f.to_json());
        }
        let mut timings = Json::Arr(vec![]);
        for st in &self.stage_timings {
            timings.push(st.to_json());
        }
        j.set("stage_timings", timings);
        if let Some(g) = &self.golden {
            let mut gj = Json::obj();
            gj.set("checked", g.checked).set("ok", g.ok).set("detail", g.detail.as_str());
            j.set("golden", gj);
        }
        if !self.golden_seeds.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for g in &self.golden_seeds {
                let mut gj = Json::obj();
                gj.set("checked", g.checked).set("ok", g.ok).set("detail", g.detail.as_str());
                arr.push(gj);
            }
            j.set("golden_seeds", arr);
        }
        j
    }
}

/// Aggregate metrics for a set of task results.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub total: usize,
    pub compiled: usize,
    pub correct: usize,
    pub fast02: usize,
    pub fast08: usize,
    pub fast10: usize,
}

impl Metrics {
    pub fn from_results<'a>(results: impl Iterator<Item = &'a TaskResult>) -> Metrics {
        let mut m = Metrics::default();
        for r in results {
            m.total += 1;
            m.compiled += r.compiled as usize;
            m.correct += r.correct as usize;
            m.fast02 += r.fast_at(0.2) as usize;
            m.fast08 += r.fast_at(0.8) as usize;
            m.fast10 += r.fast_at(1.0) as usize;
        }
        m
    }

    pub fn pct(num: usize, den: usize) -> f64 {
        if den == 0 {
            0.0
        } else {
            100.0 * num as f64 / den as f64
        }
    }

    pub fn comp_pct(&self) -> f64 {
        Metrics::pct(self.compiled, self.total)
    }
    pub fn pass_pct(&self) -> f64 {
        Metrics::pct(self.correct, self.total)
    }
    pub fn fast02_pct(&self) -> f64 {
        Metrics::pct(self.fast02, self.total)
    }
    pub fn fast08_pct(&self) -> f64 {
        Metrics::pct(self.fast08, self.total)
    }
    pub fn fast10_pct(&self) -> f64 {
        Metrics::pct(self.fast10, self.total)
    }
}

/// One rendered row of Table 1 / Table 2.
#[derive(Clone, Debug)]
pub struct CategoryRow {
    pub category: String,
    pub metrics: Metrics,
}

/// Full-suite result with table renderers.
#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub results: Vec<TaskResult>,
}

impl SuiteResult {
    pub fn by_category(&self) -> Vec<CategoryRow> {
        let mut groups: BTreeMap<Category, Vec<&TaskResult>> = BTreeMap::new();
        for r in &self.results {
            groups.entry(r.category).or_default().push(r);
        }
        groups
            .into_iter()
            .map(|(c, rs)| CategoryRow {
                category: format!("{} ({} kernels)", c.name(), rs.len()),
                metrics: Metrics::from_results(rs.into_iter()),
            })
            .collect()
    }

    pub fn totals(&self) -> Metrics {
        Metrics::from_results(self.results.iter())
    }

    /// Number of tasks whose golden cross-check executed an artifact.
    pub fn golden_checked(&self) -> usize {
        self.results
            .iter()
            .filter(|r| r.golden.as_ref().map_or(false, |g| g.checked))
            .count()
    }

    /// Tasks whose golden cross-check ran and failed.
    pub fn golden_failures(&self) -> Vec<&TaskResult> {
        self.results
            .iter()
            .filter(|r| r.golden.as_ref().map_or(false, |g| g.checked && !g.ok))
            .collect()
    }

    /// Render Table 1 (correctness by category) as aligned text.
    pub fn render_table1(&self) -> String {
        let mut s = String::new();
        s.push_str("Table 1. Correctness evaluation by category.\n");
        s.push_str(&format!("{:<28} {:>8} {:>8}\n", "Kernel Category", "Comp@1", "Pass@1"));
        for row in self.by_category() {
            s.push_str(&format!(
                "{:<28} {:>8.1} {:>8.1}\n",
                row.category,
                row.metrics.comp_pct(),
                row.metrics.pass_pct()
            ));
        }
        let t = self.totals();
        s.push_str(&format!(
            "{:<28} {:>8.1} {:>8.1}\n",
            format!("Total ({} kernels)", t.total),
            t.comp_pct(),
            t.pass_pct()
        ));
        s
    }

    /// Render the per-task failure table: one aligned row per failed task
    /// with the structured diagnostic's stage, code, and message. Empty
    /// string when every task verified.
    pub fn render_failures(&self) -> String {
        let failed: Vec<&TaskResult> =
            self.results.iter().filter(|r| r.failure.is_some()).collect();
        if failed.is_empty() {
            return String::new();
        }
        let mut s = String::new();
        s.push_str(&format!(
            "Failures ({} tasks).\n{:<18} {:<10} {:<6} message\n",
            failed.len(),
            "Task",
            "Stage",
            "Code"
        ));
        for r in failed {
            let d = r.failure.as_ref().unwrap();
            s.push_str(&format!("{:<18} {:<10} {:<6} {}\n", r.name, d.stage, d.code, d.message));
        }
        s
    }

    /// Suite-wide analyzer-finding totals: (errors, warnings, tasks with
    /// at least one finding).
    pub fn analysis_totals(&self) -> (usize, usize, usize) {
        let errors = self.results.iter().map(|r| r.analysis_errors).sum();
        let warnings = self.results.iter().map(|r| r.analysis_warnings).sum();
        let tasks = self
            .results
            .iter()
            .filter(|r| r.analysis_errors + r.analysis_warnings > 0)
            .count();
        (errors, warnings, tasks)
    }

    /// Render per-suite static-analyzer statistics: one aligned row per
    /// task with findings. Empty string when the whole suite analyzed
    /// clean (the expected steady state).
    pub fn render_analysis(&self) -> String {
        let (errors, warnings, tasks) = self.analysis_totals();
        if errors + warnings == 0 {
            return String::new();
        }
        let mut s = String::new();
        s.push_str(&format!(
            "Static analysis ({errors} errors, {warnings} warnings across {tasks} tasks).\n\
             {:<18} {:>8} {:>9}\n",
            "Task", "Errors", "Warnings"
        ));
        for r in &self.results {
            if r.analysis_errors + r.analysis_warnings > 0 {
                s.push_str(&format!(
                    "{:<18} {:>8} {:>9}\n",
                    r.name, r.analysis_errors, r.analysis_warnings
                ));
            }
        }
        s
    }

    /// Render Table 2 (performance by category) as aligned text. A run
    /// on a timing-less backend (no result carries cycles, e.g. cpu-ref)
    /// has no Fastₓ story at all: its cells render as `-` rather than a
    /// 0.0 that reads as "measured and never fast".
    pub fn render_table2(&self) -> String {
        let timed = self.results.iter().any(|r| r.generated_cycles.is_some());
        let fast = |pct: f64| {
            if timed {
                format!("{pct:>10.1}")
            } else {
                format!("{:>10}", "-")
            }
        };
        let mut s = String::new();
        s.push_str("Table 2. Performance vs PyTorch-eager baseline by category.\n");
        s.push_str(&format!(
            "{:<28} {:>10} {:>10} {:>10}\n",
            "Kernel Category", "Fast0.2@1", "Fast0.8@1", "Fast1.0@1"
        ));
        for row in self.by_category() {
            s.push_str(&format!(
                "{:<28} {} {} {}\n",
                row.category,
                fast(row.metrics.fast02_pct()),
                fast(row.metrics.fast08_pct()),
                fast(row.metrics.fast10_pct())
            ));
        }
        let t = self.totals();
        s.push_str(&format!(
            "{:<28} {} {} {}\n",
            "Total",
            fast(t.fast02_pct()),
            fast(t.fast08_pct()),
            fast(t.fast10_pct())
        ));
        s
    }

    pub fn to_json(&self) -> Json {
        let mut tasks = Json::Arr(vec![]);
        for r in &self.results {
            tasks.push(r.to_json());
        }
        let t = self.totals();
        let (a_err, a_warn, a_tasks) = self.analysis_totals();
        let mut totals = Json::obj();
        totals
            .set("comp_pct", t.comp_pct())
            .set("pass_pct", t.pass_pct())
            .set("fast02_pct", t.fast02_pct())
            .set("fast08_pct", t.fast08_pct())
            .set("fast10_pct", t.fast10_pct())
            .set("analysis_errors", a_err)
            .set("analysis_warnings", a_warn)
            .set("analysis_flagged_tasks", a_tasks);
        let mut j = Json::obj();
        j.set("tasks", tasks).set("totals", totals);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(cat: Category, compiled: bool, correct: bool, gen: Option<f64>, eager: f64) -> TaskResult {
        TaskResult {
            name: "t".into(),
            category: cat,
            backend: "ascend-sim".into(),
            compiled,
            correct,
            generated_cycles: gen,
            eager_cycles: eager,
            failure: None,
            repair_rounds: 0,
            analysis_errors: 0,
            analysis_warnings: 0,
            pipeline_secs: 0.0,
            stage_timings: Vec::new(),
            golden: None,
            golden_seeds: Vec::new(),
        }
    }

    #[test]
    fn failure_table_lists_stage_and_code() {
        let mut bad = result(Category::Math, true, false, Some(1.0), 1.0);
        bad.failure = Some(Diagnostic::new("score", "N103", "output 'y': drift"));
        let ok = result(Category::Math, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![ok.clone(), bad] };
        let table = s.render_failures();
        assert!(table.contains("score"), "{table}");
        assert!(table.contains("N103"), "{table}");
        assert!(table.contains("drift"), "{table}");
        let none = SuiteResult { results: vec![ok] };
        assert!(none.render_failures().is_empty());
    }

    #[test]
    fn task_json_includes_structured_failure_and_stage_timings() {
        use crate::coordinator::stage::StageOutcome;
        let mut r = result(Category::Loss, false, false, None, 1.0);
        r.failure = Some(Diagnostic::new("compile", "A402", "bool has no UB mapping"));
        r.stage_timings = vec![
            StageReport { name: "generate", wall_secs: 0.001, outcome: StageOutcome::Ok },
            StageReport { name: "transpile", wall_secs: 0.002, outcome: StageOutcome::Failed },
        ];
        let text = r.to_json().to_string();
        assert!(text.contains("\"failure\""), "{text}");
        assert!(text.contains("\"code\":\"A402\""), "{text}");
        assert!(text.contains("\"stage_timings\""), "{text}");
        assert!(text.contains("\"outcome\":\"failed\""), "{text}");
        assert!(text.contains("\"backend\":\"ascend-sim\""), "{text}");
    }

    #[test]
    fn golden_summary_counts_checked_and_failed() {
        let mut a = result(Category::Loss, true, true, Some(1.0), 1.0);
        a.golden = Some(GoldenStatus { checked: true, ok: true, detail: "ok".into() });
        let mut b = result(Category::Loss, true, true, Some(1.0), 1.0);
        b.golden = Some(GoldenStatus { checked: true, ok: false, detail: "drift".into() });
        let mut c = result(Category::Loss, true, true, Some(1.0), 1.0);
        c.golden = Some(GoldenStatus { checked: false, ok: true, detail: "no artifact".into() });
        let d = result(Category::Loss, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![a, b, c, d] };
        assert_eq!(s.golden_checked(), 2);
        assert_eq!(s.golden_failures().len(), 1);
        assert!(s.to_json().to_string().contains("\"golden\""));
    }

    #[test]
    fn speedup_and_fast_thresholds() {
        let r = result(Category::Activation, true, true, Some(500.0), 1000.0);
        assert_eq!(r.speedup(), Some(2.0));
        assert!(r.fast_at(0.2) && r.fast_at(0.8) && r.fast_at(1.0) && r.fast_at(2.0));
        assert!(!r.fast_at(2.1));
    }

    #[test]
    fn incorrect_kernels_are_never_fast() {
        let r = result(Category::Loss, true, false, Some(1.0), 1000.0);
        assert_eq!(r.speedup(), None);
        assert!(!r.fast_at(0.2));
    }

    #[test]
    fn metrics_percentages() {
        let rs = vec![
            result(Category::Loss, true, true, Some(500.0), 1000.0), // 2.0x
            result(Category::Loss, true, true, Some(2000.0), 1000.0), // 0.5x
            result(Category::Loss, false, false, None, 1000.0),
        ];
        let m = Metrics::from_results(rs.iter());
        assert_eq!(m.total, 3);
        assert!((m.comp_pct() - 66.7).abs() < 0.1);
        assert!((m.pass_pct() - 66.7).abs() < 0.1);
        assert!((m.fast02_pct() - 66.7).abs() < 0.1);
        assert!((m.fast10_pct() - 33.3).abs() < 0.1);
    }

    #[test]
    fn table_renderers_include_all_categories() {
        let rs = vec![
            result(Category::Activation, true, true, Some(1.0), 1.0),
            result(Category::Pooling, true, false, None, 1.0),
        ];
        let s = SuiteResult { results: rs };
        let t1 = s.render_table1();
        assert!(t1.contains("Activation"));
        assert!(t1.contains("Pooling"));
        assert!(t1.contains("Total"));
        let t2 = s.render_table2();
        assert!(t2.contains("Fast0.2@1"));
    }

    #[test]
    fn analysis_stats_render_and_serialize() {
        let mut flagged = result(Category::Math, true, false, None, 1.0);
        flagged.analysis_errors = 2;
        flagged.analysis_warnings = 1;
        let clean = result(Category::Math, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![clean.clone(), flagged] };
        assert_eq!(s.analysis_totals(), (2, 1, 1));
        let table = s.render_analysis();
        assert!(table.contains("2 errors"), "{table}");
        assert!(table.contains("1 warnings"), "{table}");
        let j = s.to_json().to_string();
        assert!(j.contains("\"analysis_errors\""), "{j}");
        // a clean suite renders nothing
        let quiet = SuiteResult { results: vec![clean] };
        assert!(quiet.render_analysis().is_empty());
        assert!(quiet.to_json().to_string().contains("\"analysis_flagged_tasks\":0"));
    }

    #[test]
    fn json_export_has_tasks_and_totals() {
        let s = SuiteResult {
            results: vec![result(Category::Math, true, true, Some(10.0), 100.0)],
        };
        let j = s.to_json().to_string();
        assert!(j.contains("\"totals\""));
        assert!(j.contains("\"speedup\":10"));
    }
}
