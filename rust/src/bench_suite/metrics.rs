//! Benchmark metrics: Comp@1, Pass@1, Fast₀.₂/₀.₈/₁.₀ (paper §5.1) and the
//! Table 1 / Table 2 renderers.
//!
//! Fastₓ counts a kernel when `eager_cycles / generated_cycles >= x`, i.e.
//! the generated kernel reaches at least x× the eager baseline's speed.
//! Percentages are over *all* kernels in a category (incorrect kernels can
//! never be fast), matching the paper's arithmetic (e.g. Loss Fast = 85.7%
//! = 6/7 with one incorrect kernel).

use super::spec::Category;
use crate::coordinator::stage::{Diagnostic, StageReport};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Outcome of the L2↔L3 golden cross-check that `run_suite` performs per
/// task when `SuiteConfig::golden` is set: the JAX golden oracle (HLO
/// executed by the compiled plan) compared against the Rust reference.
#[derive(Clone, Debug, PartialEq)]
pub struct GoldenStatus {
    /// An artifact existed and was executed (false = vacuous pass).
    pub checked: bool,
    /// Oracle and Rust reference agreed within tolerance.
    pub ok: bool,
    pub detail: String,
}

impl GoldenStatus {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("checked", self.checked).set("ok", self.ok).set("detail", self.detail.as_str());
        j
    }

    /// Inverse of [`GoldenStatus::to_json`]; `None` on a malformed object.
    pub fn from_json(j: &Json) -> Option<GoldenStatus> {
        Some(GoldenStatus {
            checked: j.get("checked")?.as_bool()?,
            ok: j.get("ok")?.as_bool()?,
            detail: j.get("detail")?.as_str()?.to_string(),
        })
    }
}

/// Outcome of one task through the full pipeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskResult {
    pub name: String,
    pub category: Category,
    /// Name of the execution backend that produced this result (the
    /// default suite runs on `"ascend-sim"`).
    pub backend: String,
    pub compiled: bool,
    pub correct: bool,
    /// Simulated cycles of the generated kernel (if it ran).
    pub generated_cycles: Option<f64>,
    /// Simulated cycles of the eager baseline.
    pub eager_cycles: f64,
    /// Structured failure: the diagnostic of the stage that stopped the
    /// pipeline (None when the task verified end to end).
    pub failure: Option<Diagnostic>,
    /// Number of repair-feedback rounds consumed across passes.
    pub repair_rounds: usize,
    /// Error-severity findings from the static analyzer (`ASCAN###`
    /// codes; 0 for tasks that never reached the analyze stage).
    pub analysis_errors: usize,
    /// Warning-severity analyzer findings.
    pub analysis_warnings: usize,
    /// Wall-clock seconds the pipeline spent on this task.
    pub pipeline_secs: f64,
    /// Per-stage wall time + outcome, in execution order (the session's
    /// stage reports; empty only for hand-built results).
    pub stage_timings: Vec<StageReport>,
    /// Golden cross-check outcome (None when the suite ran without it).
    /// When the check ran over several seeds this is the aggregate;
    /// per-seed outcomes are in [`TaskResult::golden_seeds`].
    pub golden: Option<GoldenStatus>,
    /// Per-seed golden cross-check outcomes, in seed order (empty when
    /// the suite ran without `--golden`).
    pub golden_seeds: Vec<GoldenStatus>,
}

impl TaskResult {
    /// eager/generated speed ratio (>= 1.0 means generated wins).
    pub fn speedup(&self) -> Option<f64> {
        match (self.correct, self.generated_cycles) {
            (true, Some(g)) if g > 0.0 => Some(self.eager_cycles / g),
            _ => None,
        }
    }

    pub fn fast_at(&self, x: f64) -> bool {
        self.speedup().map(|s| s >= x).unwrap_or(false)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name.as_str())
            .set("category", self.category.name())
            .set("backend", self.backend.as_str())
            .set("compiled", self.compiled)
            .set("correct", self.correct)
            .set("eager_cycles", self.eager_cycles)
            .set("repair_rounds", self.repair_rounds)
            .set("analysis_errors", self.analysis_errors)
            .set("analysis_warnings", self.analysis_warnings)
            .set("pipeline_secs", self.pipeline_secs);
        match self.generated_cycles {
            Some(g) => j.set("generated_cycles", g),
            None => j.set("generated_cycles", Json::Null),
        };
        match self.speedup() {
            Some(s) => j.set("speedup", s),
            None => j.set("speedup", Json::Null),
        };
        if let Some(f) = &self.failure {
            j.set("failure", f.to_json());
        }
        let mut timings = Json::Arr(vec![]);
        for st in &self.stage_timings {
            timings.push(st.to_json());
        }
        j.set("stage_timings", timings);
        if let Some(g) = &self.golden {
            j.set("golden", g.to_json());
        }
        if !self.golden_seeds.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for g in &self.golden_seeds {
                arr.push(g.to_json());
            }
            j.set("golden_seeds", arr);
        }
        j
    }

    /// Inverse of [`TaskResult::to_json`] (the suite journal and
    /// `--compare` baselines load through here). `name`, `category`,
    /// `backend`, `compiled`, and `correct` are required; every other
    /// field defaults when absent, so a hand-authored baseline can state
    /// only the verdicts it wants to pin. The derived `speedup` field is
    /// ignored — it is recomputed from cycles. Returns `None` on a
    /// malformed object.
    pub fn from_json(j: &Json) -> Option<TaskResult> {
        let mut stage_timings = Vec::new();
        if let Some(arr) = j.get("stage_timings") {
            for st in arr.as_arr()? {
                stage_timings.push(StageReport::from_json(st)?);
            }
        }
        let mut golden_seeds = Vec::new();
        if let Some(arr) = j.get("golden_seeds") {
            for g in arr.as_arr()? {
                golden_seeds.push(GoldenStatus::from_json(g)?);
            }
        }
        Some(TaskResult {
            name: j.get("name")?.as_str()?.to_string(),
            category: Category::from_name(j.get("category")?.as_str()?)?,
            backend: j.get("backend")?.as_str()?.to_string(),
            compiled: j.get("compiled")?.as_bool()?,
            correct: j.get("correct")?.as_bool()?,
            generated_cycles: match j.get("generated_cycles") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64()?),
            },
            eager_cycles: match j.get("eager_cycles") {
                None => 0.0,
                Some(v) => v.as_f64()?,
            },
            failure: match j.get("failure") {
                None => None,
                Some(f) => Some(Diagnostic::from_json(f)?),
            },
            repair_rounds: match j.get("repair_rounds") {
                None => 0,
                Some(v) => v.as_f64()? as usize,
            },
            analysis_errors: match j.get("analysis_errors") {
                None => 0,
                Some(v) => v.as_f64()? as usize,
            },
            analysis_warnings: match j.get("analysis_warnings") {
                None => 0,
                Some(v) => v.as_f64()? as usize,
            },
            pipeline_secs: match j.get("pipeline_secs") {
                None => 0.0,
                Some(v) => v.as_f64()?,
            },
            stage_timings,
            golden: match j.get("golden") {
                None => None,
                Some(g) => Some(GoldenStatus::from_json(g)?),
            },
            golden_seeds,
        })
    }

    /// This result with the wall-clock measurement fields zeroed
    /// (`pipeline_secs` and per-stage `wall_secs`). Everything else the
    /// pipeline produces is deterministic at a fixed configuration, so
    /// two runs of the same tuple — or an interrupted-and-resumed run vs
    /// an uninterrupted one — compare equal under `canonical`.
    pub fn canonical(&self) -> TaskResult {
        let mut r = self.clone();
        r.pipeline_secs = 0.0;
        for st in &mut r.stage_timings {
            st.wall_secs = 0.0;
        }
        r
    }
}

/// Aggregate metrics for a set of task results.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    pub total: usize,
    pub compiled: usize,
    pub correct: usize,
    pub fast02: usize,
    pub fast08: usize,
    pub fast10: usize,
}

impl Metrics {
    pub fn from_results<'a>(results: impl Iterator<Item = &'a TaskResult>) -> Metrics {
        let mut m = Metrics::default();
        for r in results {
            m.total += 1;
            m.compiled += r.compiled as usize;
            m.correct += r.correct as usize;
            m.fast02 += r.fast_at(0.2) as usize;
            m.fast08 += r.fast_at(0.8) as usize;
            m.fast10 += r.fast_at(1.0) as usize;
        }
        m
    }

    pub fn pct(num: usize, den: usize) -> f64 {
        if den == 0 {
            0.0
        } else {
            100.0 * num as f64 / den as f64
        }
    }

    pub fn comp_pct(&self) -> f64 {
        Metrics::pct(self.compiled, self.total)
    }
    pub fn pass_pct(&self) -> f64 {
        Metrics::pct(self.correct, self.total)
    }
    pub fn fast02_pct(&self) -> f64 {
        Metrics::pct(self.fast02, self.total)
    }
    pub fn fast08_pct(&self) -> f64 {
        Metrics::pct(self.fast08, self.total)
    }
    pub fn fast10_pct(&self) -> f64 {
        Metrics::pct(self.fast10, self.total)
    }
}

/// One rendered row of Table 1 / Table 2.
#[derive(Clone, Debug)]
pub struct CategoryRow {
    pub category: String,
    pub metrics: Metrics,
}

/// Full-suite result with table renderers.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteResult {
    pub results: Vec<TaskResult>,
}

impl SuiteResult {
    pub fn by_category(&self) -> Vec<CategoryRow> {
        let mut groups: BTreeMap<Category, Vec<&TaskResult>> = BTreeMap::new();
        for r in &self.results {
            groups.entry(r.category).or_default().push(r);
        }
        groups
            .into_iter()
            .map(|(c, rs)| CategoryRow {
                category: format!("{} ({} kernels)", c.name(), rs.len()),
                metrics: Metrics::from_results(rs.into_iter()),
            })
            .collect()
    }

    pub fn totals(&self) -> Metrics {
        Metrics::from_results(self.results.iter())
    }

    /// Number of tasks whose golden cross-check executed an artifact.
    pub fn golden_checked(&self) -> usize {
        self.results
            .iter()
            .filter(|r| r.golden.as_ref().map_or(false, |g| g.checked))
            .count()
    }

    /// Tasks whose golden cross-check ran and failed.
    pub fn golden_failures(&self) -> Vec<&TaskResult> {
        self.results
            .iter()
            .filter(|r| r.golden.as_ref().map_or(false, |g| g.checked && !g.ok))
            .collect()
    }

    /// Render Table 1 (correctness by category) as aligned text.
    pub fn render_table1(&self) -> String {
        let mut s = String::new();
        s.push_str("Table 1. Correctness evaluation by category.\n");
        s.push_str(&format!("{:<28} {:>8} {:>8}\n", "Kernel Category", "Comp@1", "Pass@1"));
        for row in self.by_category() {
            s.push_str(&format!(
                "{:<28} {:>8.1} {:>8.1}\n",
                row.category,
                row.metrics.comp_pct(),
                row.metrics.pass_pct()
            ));
        }
        let t = self.totals();
        s.push_str(&format!(
            "{:<28} {:>8.1} {:>8.1}\n",
            format!("Total ({} kernels)", t.total),
            t.comp_pct(),
            t.pass_pct()
        ));
        s
    }

    /// Render the per-task failure table: one aligned row per failed task
    /// with the structured diagnostic's stage, code, and message. Empty
    /// string when every task verified.
    pub fn render_failures(&self) -> String {
        let failed: Vec<&TaskResult> =
            self.results.iter().filter(|r| r.failure.is_some()).collect();
        if failed.is_empty() {
            return String::new();
        }
        let mut s = String::new();
        s.push_str(&format!(
            "Failures ({} tasks).\n{:<18} {:<10} {:<6} message\n",
            failed.len(),
            "Task",
            "Stage",
            "Code"
        ));
        for r in failed {
            let d = r.failure.as_ref().unwrap();
            s.push_str(&format!("{:<18} {:<10} {:<6} {}\n", r.name, d.stage, d.code, d.message));
        }
        s
    }

    /// Suite-wide analyzer-finding totals: (errors, warnings, tasks with
    /// at least one finding).
    pub fn analysis_totals(&self) -> (usize, usize, usize) {
        let errors = self.results.iter().map(|r| r.analysis_errors).sum();
        let warnings = self.results.iter().map(|r| r.analysis_warnings).sum();
        let tasks = self
            .results
            .iter()
            .filter(|r| r.analysis_errors + r.analysis_warnings > 0)
            .count();
        (errors, warnings, tasks)
    }

    /// Render per-suite static-analyzer statistics: one aligned row per
    /// task with findings. Empty string when the whole suite analyzed
    /// clean (the expected steady state).
    pub fn render_analysis(&self) -> String {
        let (errors, warnings, tasks) = self.analysis_totals();
        if errors + warnings == 0 {
            return String::new();
        }
        let mut s = String::new();
        s.push_str(&format!(
            "Static analysis ({errors} errors, {warnings} warnings across {tasks} tasks).\n\
             {:<18} {:>8} {:>9}\n",
            "Task", "Errors", "Warnings"
        ));
        for r in &self.results {
            if r.analysis_errors + r.analysis_warnings > 0 {
                s.push_str(&format!(
                    "{:<18} {:>8} {:>9}\n",
                    r.name, r.analysis_errors, r.analysis_warnings
                ));
            }
        }
        s
    }

    /// Render Table 2 (performance by category) as aligned text. A run
    /// on a timing-less backend (no result carries cycles, e.g. cpu-ref)
    /// has no Fastₓ story at all: its cells render as `-` rather than a
    /// 0.0 that reads as "measured and never fast".
    pub fn render_table2(&self) -> String {
        let timed = self.results.iter().any(|r| r.generated_cycles.is_some());
        let fast = |pct: f64| {
            if timed {
                format!("{pct:>10.1}")
            } else {
                format!("{:>10}", "-")
            }
        };
        let mut s = String::new();
        s.push_str("Table 2. Performance vs PyTorch-eager baseline by category.\n");
        s.push_str(&format!(
            "{:<28} {:>10} {:>10} {:>10}\n",
            "Kernel Category", "Fast0.2@1", "Fast0.8@1", "Fast1.0@1"
        ));
        for row in self.by_category() {
            s.push_str(&format!(
                "{:<28} {} {} {}\n",
                row.category,
                fast(row.metrics.fast02_pct()),
                fast(row.metrics.fast08_pct()),
                fast(row.metrics.fast10_pct())
            ));
        }
        let t = self.totals();
        s.push_str(&format!(
            "{:<28} {} {} {}\n",
            "Total",
            fast(t.fast02_pct()),
            fast(t.fast08_pct()),
            fast(t.fast10_pct())
        ));
        s
    }

    pub fn to_json(&self) -> Json {
        let mut tasks = Json::Arr(vec![]);
        for r in &self.results {
            tasks.push(r.to_json());
        }
        let t = self.totals();
        let (a_err, a_warn, a_tasks) = self.analysis_totals();
        let mut totals = Json::obj();
        totals
            .set("comp_pct", t.comp_pct())
            .set("pass_pct", t.pass_pct())
            .set("fast02_pct", t.fast02_pct())
            .set("fast08_pct", t.fast08_pct())
            .set("fast10_pct", t.fast10_pct())
            .set("analysis_errors", a_err)
            .set("analysis_warnings", a_warn)
            .set("analysis_flagged_tasks", a_tasks);
        let mut j = Json::obj();
        j.set("tasks", tasks).set("totals", totals);
        j
    }

    /// Inverse of [`SuiteResult::to_json`]: reads the `tasks` array (the
    /// `totals` object is derived data and is recomputed, never trusted).
    /// Returns `None` on a malformed object.
    pub fn from_json(j: &Json) -> Option<SuiteResult> {
        let mut results = Vec::new();
        for t in j.get("tasks")?.as_arr()? {
            results.push(TaskResult::from_json(t)?);
        }
        Some(SuiteResult { results })
    }

    /// Per-task [`TaskResult::canonical`] over the whole suite.
    pub fn canonical(&self) -> SuiteResult {
        SuiteResult { results: self.results.iter().map(TaskResult::canonical).collect() }
    }
}

/// One aggregate metric compared against a baseline snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricDelta {
    pub name: &'static str,
    /// Percentage points, recomputed from the baseline's task records.
    pub baseline: f64,
    pub current: f64,
}

impl MetricDelta {
    /// A drop in the aggregate is a regression; equal-or-better is not.
    /// The epsilon absorbs float noise from recomputing percentages.
    pub fn regressed(&self) -> bool {
        self.current < self.baseline - 1e-9
    }
}

/// A per-task verdict that differs from the baseline. `baseline: true,
/// current: false` is a regression; the opposite direction is an
/// improvement (reported, never gated).
#[derive(Clone, Debug, PartialEq)]
pub struct VerdictChange {
    pub task: String,
    /// Which verdict flipped: `compiled`, `correct`, or `fast0.2/0.8/1.0`.
    pub what: &'static str,
    pub baseline: bool,
    pub current: bool,
}

impl VerdictChange {
    pub fn regressed(&self) -> bool {
        self.baseline && !self.current
    }
}

/// Aggregate metric deltas restricted to one operator category (the
/// per-category rollup `suite --compare` and `suite --tuned` print next
/// to the per-task verdict list). Informational only: the exit-1 gate
/// stays on the suite-wide metrics and per-task verdict flips, which
/// already subsume any category-level drop.
#[derive(Clone, Debug, PartialEq)]
pub struct CategoryDelta {
    pub category: Category,
    /// Same five rows as [`SuiteDelta::metrics`], over this category's
    /// tasks only.
    pub metrics: Vec<MetricDelta>,
}

impl CategoryDelta {
    /// Any metric of this category dropped.
    pub fn regressed(&self) -> bool {
        self.metrics.iter().any(MetricDelta::regressed)
    }
}

/// The diff `suite --compare BASELINE.json` renders and gates on:
/// aggregate metric deltas, per-task verdict flips, and coverage changes.
#[derive(Clone, Debug, PartialEq)]
pub struct SuiteDelta {
    /// Comp@1 / Pass@1 / Fastₓ, in render order (always five entries).
    pub metrics: Vec<MetricDelta>,
    /// The same five metrics rolled up per operator category, in
    /// [`Category`] order; categories present on either side appear.
    pub categories: Vec<CategoryDelta>,
    /// Per-task verdicts that changed in either direction.
    pub verdicts: Vec<VerdictChange>,
    /// Baseline tasks absent from the current run — lost coverage is a
    /// regression.
    pub missing: Vec<String>,
    /// Current tasks the baseline doesn't know (informational only).
    pub added: Vec<String>,
}

impl SuiteDelta {
    /// The `--compare` exit-1 condition: any metric drop, any true→false
    /// verdict flip, or any baseline task missing from the current run.
    pub fn regressed(&self) -> bool {
        !self.missing.is_empty()
            || self.metrics.iter().any(MetricDelta::regressed)
            || self.verdicts.iter().any(VerdictChange::regressed)
    }

    /// Render the delta table (aligned text, same style as Tables 1+2).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("Baseline comparison.\n");
        s.push_str(&format!(
            "{:<12} {:>10} {:>10} {:>8}\n",
            "Metric", "baseline", "current", "delta"
        ));
        for m in &self.metrics {
            s.push_str(&format!(
                "{:<12} {:>10.1} {:>10.1} {:>+8.1}{}\n",
                m.name,
                m.baseline,
                m.current,
                m.current - m.baseline,
                if m.regressed() { "  REGRESSED" } else { "" }
            ));
        }
        if !self.categories.is_empty() {
            s.push_str("Per-category deltas (percentage points).\n");
            s.push_str(&format!(
                "{:<14} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
                "Category", "Comp@1", "Pass@1", "Fast0.2", "Fast0.8", "Fast1.0"
            ));
            for c in &self.categories {
                s.push_str(&format!("{:<14}", c.category.name()));
                for m in &c.metrics {
                    s.push_str(&format!(" {:>+9.1}", m.current - m.baseline));
                }
                if c.regressed() {
                    s.push_str("  REGRESSED");
                }
                s.push('\n');
            }
        }
        for v in &self.verdicts {
            s.push_str(&format!(
                "verdict {:<18} {:<9} {} -> {}{}\n",
                v.task,
                v.what,
                v.baseline,
                v.current,
                if v.regressed() { "  REGRESSED" } else { "  improved" }
            ));
        }
        for t in &self.missing {
            s.push_str(&format!("missing from current run: {t}  REGRESSED\n"));
        }
        for t in &self.added {
            s.push_str(&format!("new task (not in baseline): {t}\n"));
        }
        s.push_str(if self.regressed() {
            "verdict: REGRESSED vs baseline\n"
        } else {
            "verdict: no regression vs baseline\n"
        });
        s
    }
}

/// Diff a current suite run against a baseline snapshot. Aggregates are
/// recomputed from each side's task records (so a conservative
/// hand-authored baseline — verdicts only, no cycles — can never gate on
/// a Fastₓ value it didn't claim: missing cycles make `fast_at` false,
/// which current runs can only match or beat). Tasks are matched by name.
pub fn compare_suites(baseline: &SuiteResult, current: &SuiteResult) -> SuiteDelta {
    let metric_rows = |b: &Metrics, c: &Metrics| {
        vec![
            MetricDelta { name: "Comp@1", baseline: b.comp_pct(), current: c.comp_pct() },
            MetricDelta { name: "Pass@1", baseline: b.pass_pct(), current: c.pass_pct() },
            MetricDelta { name: "Fast0.2@1", baseline: b.fast02_pct(), current: c.fast02_pct() },
            MetricDelta { name: "Fast0.8@1", baseline: b.fast08_pct(), current: c.fast08_pct() },
            MetricDelta { name: "Fast1.0@1", baseline: b.fast10_pct(), current: c.fast10_pct() },
        ]
    };
    let metrics = metric_rows(&baseline.totals(), &current.totals());
    // Per-category rollup: same five rows, restricted per category. A
    // category present on only one side still gets a row (the other
    // side's metrics are the empty Metrics — 0% everywhere).
    let mut cats: std::collections::BTreeSet<Category> = std::collections::BTreeSet::new();
    cats.extend(baseline.results.iter().map(|r| r.category));
    cats.extend(current.results.iter().map(|r| r.category));
    let of = |suite: &SuiteResult, cat: Category| {
        Metrics::from_results(suite.results.iter().filter(|r| r.category == cat))
    };
    let categories = cats
        .into_iter()
        .map(|cat| CategoryDelta {
            category: cat,
            metrics: metric_rows(&of(baseline, cat), &of(current, cat)),
        })
        .collect();
    let by_name: BTreeMap<&str, &TaskResult> =
        current.results.iter().map(|r| (r.name.as_str(), r)).collect();
    let mut verdicts = Vec::new();
    let mut missing = Vec::new();
    for b in &baseline.results {
        let Some(c) = by_name.get(b.name.as_str()) else {
            missing.push(b.name.clone());
            continue;
        };
        let checks: [(&'static str, bool, bool); 5] = [
            ("compiled", b.compiled, c.compiled),
            ("correct", b.correct, c.correct),
            ("fast0.2", b.fast_at(0.2), c.fast_at(0.2)),
            ("fast0.8", b.fast_at(0.8), c.fast_at(0.8)),
            ("fast1.0", b.fast_at(1.0), c.fast_at(1.0)),
        ];
        for (what, bv, cv) in checks {
            if bv != cv {
                verdicts.push(VerdictChange {
                    task: b.name.clone(),
                    what,
                    baseline: bv,
                    current: cv,
                });
            }
        }
    }
    let base_names: std::collections::BTreeSet<&str> =
        baseline.results.iter().map(|r| r.name.as_str()).collect();
    let added = current
        .results
        .iter()
        .filter(|r| !base_names.contains(r.name.as_str()))
        .map(|r| r.name.clone())
        .collect();
    SuiteDelta { metrics, categories, verdicts, missing, added }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(cat: Category, compiled: bool, correct: bool, gen: Option<f64>, eager: f64) -> TaskResult {
        TaskResult {
            name: "t".into(),
            category: cat,
            backend: "ascend-sim".into(),
            compiled,
            correct,
            generated_cycles: gen,
            eager_cycles: eager,
            failure: None,
            repair_rounds: 0,
            analysis_errors: 0,
            analysis_warnings: 0,
            pipeline_secs: 0.0,
            stage_timings: Vec::new(),
            golden: None,
            golden_seeds: Vec::new(),
        }
    }

    #[test]
    fn failure_table_lists_stage_and_code() {
        let mut bad = result(Category::Math, true, false, Some(1.0), 1.0);
        bad.failure = Some(Diagnostic::new("score", "N103", "output 'y': drift"));
        let ok = result(Category::Math, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![ok.clone(), bad] };
        let table = s.render_failures();
        assert!(table.contains("score"), "{table}");
        assert!(table.contains("N103"), "{table}");
        assert!(table.contains("drift"), "{table}");
        let none = SuiteResult { results: vec![ok] };
        assert!(none.render_failures().is_empty());
    }

    #[test]
    fn task_json_includes_structured_failure_and_stage_timings() {
        use crate::coordinator::stage::StageOutcome;
        let mut r = result(Category::Loss, false, false, None, 1.0);
        r.failure = Some(Diagnostic::new("compile", "A402", "bool has no UB mapping"));
        r.stage_timings = vec![
            StageReport { name: "generate", wall_secs: 0.001, outcome: StageOutcome::Ok },
            StageReport { name: "transpile", wall_secs: 0.002, outcome: StageOutcome::Failed },
        ];
        let text = r.to_json().to_string();
        assert!(text.contains("\"failure\""), "{text}");
        assert!(text.contains("\"code\":\"A402\""), "{text}");
        assert!(text.contains("\"stage_timings\""), "{text}");
        assert!(text.contains("\"outcome\":\"failed\""), "{text}");
        assert!(text.contains("\"backend\":\"ascend-sim\""), "{text}");
    }

    #[test]
    fn golden_summary_counts_checked_and_failed() {
        let mut a = result(Category::Loss, true, true, Some(1.0), 1.0);
        a.golden = Some(GoldenStatus { checked: true, ok: true, detail: "ok".into() });
        let mut b = result(Category::Loss, true, true, Some(1.0), 1.0);
        b.golden = Some(GoldenStatus { checked: true, ok: false, detail: "drift".into() });
        let mut c = result(Category::Loss, true, true, Some(1.0), 1.0);
        c.golden = Some(GoldenStatus { checked: false, ok: true, detail: "no artifact".into() });
        let d = result(Category::Loss, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![a, b, c, d] };
        assert_eq!(s.golden_checked(), 2);
        assert_eq!(s.golden_failures().len(), 1);
        assert!(s.to_json().to_string().contains("\"golden\""));
    }

    #[test]
    fn speedup_and_fast_thresholds() {
        let r = result(Category::Activation, true, true, Some(500.0), 1000.0);
        assert_eq!(r.speedup(), Some(2.0));
        assert!(r.fast_at(0.2) && r.fast_at(0.8) && r.fast_at(1.0) && r.fast_at(2.0));
        assert!(!r.fast_at(2.1));
    }

    #[test]
    fn incorrect_kernels_are_never_fast() {
        let r = result(Category::Loss, true, false, Some(1.0), 1000.0);
        assert_eq!(r.speedup(), None);
        assert!(!r.fast_at(0.2));
    }

    #[test]
    fn metrics_percentages() {
        let rs = vec![
            result(Category::Loss, true, true, Some(500.0), 1000.0), // 2.0x
            result(Category::Loss, true, true, Some(2000.0), 1000.0), // 0.5x
            result(Category::Loss, false, false, None, 1000.0),
        ];
        let m = Metrics::from_results(rs.iter());
        assert_eq!(m.total, 3);
        assert!((m.comp_pct() - 66.7).abs() < 0.1);
        assert!((m.pass_pct() - 66.7).abs() < 0.1);
        assert!((m.fast02_pct() - 66.7).abs() < 0.1);
        assert!((m.fast10_pct() - 33.3).abs() < 0.1);
    }

    #[test]
    fn table_renderers_include_all_categories() {
        let rs = vec![
            result(Category::Activation, true, true, Some(1.0), 1.0),
            result(Category::Pooling, true, false, None, 1.0),
        ];
        let s = SuiteResult { results: rs };
        let t1 = s.render_table1();
        assert!(t1.contains("Activation"));
        assert!(t1.contains("Pooling"));
        assert!(t1.contains("Total"));
        let t2 = s.render_table2();
        assert!(t2.contains("Fast0.2@1"));
    }

    #[test]
    fn analysis_stats_render_and_serialize() {
        let mut flagged = result(Category::Math, true, false, None, 1.0);
        flagged.analysis_errors = 2;
        flagged.analysis_warnings = 1;
        let clean = result(Category::Math, true, true, Some(1.0), 1.0);
        let s = SuiteResult { results: vec![clean.clone(), flagged] };
        assert_eq!(s.analysis_totals(), (2, 1, 1));
        let table = s.render_analysis();
        assert!(table.contains("2 errors"), "{table}");
        assert!(table.contains("1 warnings"), "{table}");
        let j = s.to_json().to_string();
        assert!(j.contains("\"analysis_errors\""), "{j}");
        // a clean suite renders nothing
        let quiet = SuiteResult { results: vec![clean] };
        assert!(quiet.render_analysis().is_empty());
        assert!(quiet.to_json().to_string().contains("\"analysis_flagged_tasks\":0"));
    }

    #[test]
    fn json_export_has_tasks_and_totals() {
        let s = SuiteResult {
            results: vec![result(Category::Math, true, true, Some(10.0), 100.0)],
        };
        let j = s.to_json().to_string();
        assert!(j.contains("\"totals\""));
        assert!(j.contains("\"speedup\":10"));
    }

    #[test]
    fn task_result_json_round_trips() {
        use crate::coordinator::stage::StageOutcome;
        let mut r = result(Category::Loss, true, false, Some(123.5), 1000.0);
        r.failure = Some(Diagnostic::new("score", "N103", "output 'y': drift").with_line(3));
        r.stage_timings = vec![
            StageReport { name: "generate", wall_secs: 0.001, outcome: StageOutcome::Ok },
            StageReport { name: "score", wall_secs: 0.25, outcome: StageOutcome::Failed },
        ];
        r.repair_rounds = 2;
        r.analysis_warnings = 1;
        r.pipeline_secs = 0.875;
        r.golden = Some(GoldenStatus { checked: true, ok: true, detail: "2 seeds".into() });
        r.golden_seeds = vec![
            GoldenStatus { checked: true, ok: true, detail: "seed 0".into() },
            GoldenStatus { checked: true, ok: true, detail: "seed 1".into() },
        ];
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(TaskResult::from_json(&parsed), Some(r));
    }

    #[test]
    fn task_result_from_json_defaults_optional_fields() {
        let j = Json::parse(
            r#"{"backend":"ascend-sim","category":"Math","compiled":true,"correct":true,"name":"relu"}"#,
        )
        .unwrap();
        let r = TaskResult::from_json(&j).unwrap();
        assert_eq!(r.name, "relu");
        assert!(r.compiled && r.correct);
        assert_eq!(r.generated_cycles, None);
        assert!(r.stage_timings.is_empty() && r.golden.is_none());
        // a verdict-only record is never "fast" — missing cycles can't gate
        assert!(!r.fast_at(0.2));
        // required fields missing → malformed
        let bad = Json::parse(r#"{"name":"relu","compiled":true}"#).unwrap();
        assert_eq!(TaskResult::from_json(&bad), None);
    }

    #[test]
    fn suite_result_json_round_trips_and_canonical_zeroes_clocks() {
        let mut a = result(Category::Math, true, true, Some(10.0), 100.0);
        a.pipeline_secs = 1.5;
        a.stage_timings = vec![StageReport {
            name: "generate",
            wall_secs: 0.5,
            outcome: crate::coordinator::stage::StageOutcome::Ok,
        }];
        let s = SuiteResult { results: vec![a] };
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(SuiteResult::from_json(&parsed), Some(s.clone()));
        let canon = s.canonical();
        assert_eq!(canon.results[0].pipeline_secs, 0.0);
        assert_eq!(canon.results[0].stage_timings[0].wall_secs, 0.0);
        // everything that isn't a clock survives
        assert_eq!(canon.results[0].generated_cycles, Some(10.0));
        // two runs differing only in wall time are canonical-equal
        let mut b = s.clone();
        b.results[0].pipeline_secs = 9.0;
        assert_ne!(b, s);
        assert_eq!(b.canonical(), s.canonical());
    }

    #[test]
    fn compare_flags_metric_and_verdict_regressions() {
        let mut ok = result(Category::Math, true, true, Some(500.0), 1000.0);
        ok.name = "a".into();
        let mut slow = ok.clone();
        slow.name = "b".into();
        let baseline = SuiteResult { results: vec![ok.clone(), slow.clone()] };
        // identical run: no regression, five metric rows
        let delta = compare_suites(&baseline, &baseline);
        assert!(!delta.regressed());
        assert_eq!(delta.metrics.len(), 5);
        assert!(delta.verdicts.is_empty() && delta.missing.is_empty());
        // a task goes incorrect: verdict + Pass@1 + Fastₓ regress
        let mut broken = slow.clone();
        broken.correct = false;
        let current = SuiteResult { results: vec![ok.clone(), broken] };
        let delta = compare_suites(&baseline, &current);
        assert!(delta.regressed());
        assert!(delta
            .verdicts
            .iter()
            .any(|v| v.task == "b" && v.what == "correct" && v.regressed()));
        assert!(delta.metrics.iter().any(|m| m.name == "Pass@1" && m.regressed()));
        let rendered = delta.render();
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        // a slower kernel: fast verdict flips without touching Pass@1
        let mut crawling = slow.clone();
        crawling.generated_cycles = Some(2000.0); // 0.5x
        let current = SuiteResult { results: vec![ok.clone(), crawling] };
        let delta = compare_suites(&baseline, &current);
        assert!(delta.regressed());
        assert!(delta.verdicts.iter().any(|v| v.what == "fast0.8" && v.regressed()));
        assert!(delta.metrics.iter().any(|m| m.name == "Pass@1" && !m.regressed()));
    }

    #[test]
    fn compare_rolls_metrics_up_per_category() {
        let mut act = result(Category::Activation, true, true, Some(500.0), 1000.0);
        act.name = "act".into();
        let mut loss = result(Category::Loss, true, true, Some(2000.0), 1000.0); // 0.5x
        loss.name = "loss".into();
        let baseline = SuiteResult { results: vec![act.clone(), loss.clone()] };
        // the loss kernel gets faster: its category's Fast rows move, the
        // activation category's stay put
        let mut tuned_loss = loss.clone();
        tuned_loss.generated_cycles = Some(800.0); // 1.25x
        let current = SuiteResult { results: vec![act.clone(), tuned_loss] };
        let delta = compare_suites(&baseline, &current);
        assert_eq!(delta.categories.len(), 2);
        let row = |cat: Category| delta.categories.iter().find(|c| c.category == cat).unwrap();
        let loss_row = row(Category::Loss);
        assert!(!loss_row.regressed());
        let fast10 = loss_row.metrics.iter().find(|m| m.name == "Fast1.0@1").unwrap();
        assert_eq!((fast10.baseline, fast10.current), (0.0, 100.0));
        let act_row = row(Category::Activation);
        assert!(act_row.metrics.iter().all(|m| m.baseline == m.current));
        let rendered = delta.render();
        assert!(rendered.contains("Per-category deltas"), "{rendered}");
        assert!(rendered.contains("Loss"), "{rendered}");
        assert!(rendered.contains("+100.0"), "{rendered}");
        // a category-level drop renders REGRESSED on its row
        let mut slow_act = act.clone();
        slow_act.generated_cycles = Some(9000.0);
        let worse = SuiteResult { results: vec![slow_act, loss.clone()] };
        let delta = compare_suites(&baseline, &worse);
        assert!(row_of(&delta, Category::Activation).regressed());
        assert!(delta.render().contains("REGRESSED"));
    }

    fn row_of(delta: &SuiteDelta, cat: Category) -> &CategoryDelta {
        delta.categories.iter().find(|c| c.category == cat).unwrap()
    }

    #[test]
    fn compare_flags_missing_tasks_and_reports_improvements() {
        let mut was_bad = result(Category::Math, true, false, None, 1000.0);
        was_bad.name = "a".into();
        let baseline = SuiteResult { results: vec![was_bad] };
        // the task improves and a new task appears: no regression
        let mut now_good = result(Category::Math, true, true, Some(500.0), 1000.0);
        now_good.name = "a".into();
        let mut extra = now_good.clone();
        extra.name = "z".into();
        let current = SuiteResult { results: vec![now_good, extra] };
        let delta = compare_suites(&baseline, &current);
        assert!(!delta.regressed());
        assert!(delta.verdicts.iter().any(|v| v.what == "correct" && !v.regressed()));
        assert_eq!(delta.added, vec!["z".to_string()]);
        assert!(delta.render().contains("improved"));
        // dropping a baseline task is lost coverage → regression
        let empty = SuiteResult { results: vec![] };
        let delta = compare_suites(&baseline, &empty);
        assert!(delta.regressed());
        assert_eq!(delta.missing, vec!["a".to_string()]);
    }
}
