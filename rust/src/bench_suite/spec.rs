//! Task specification model: what each benchmark kernel computes, how the
//! PyTorch-eager baseline would execute it, and reference numerics.
//!
//! The `ComputeSpec` is the machine-readable task description the
//! synthesizer's category templates consume — the analogue of the
//! "reference PyTorch implementation + input shapes" a task gives the LLM
//! in the paper's pipeline.

use crate::util::rng::XorShiftRng;
use crate::util::tensor::{DType, Tensor};
use std::collections::HashMap;

/// The paper's seven MultiKernelBench Level-1 categories.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    Activation,
    Loss,
    Math,
    Normalization,
    Optimizer,
    Reduce,
    Pooling,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Activation => "Activation",
            Category::Loss => "Loss",
            Category::Math => "Math",
            Category::Normalization => "Normalization",
            Category::Optimizer => "Optimizer",
            Category::Reduce => "Reduce",
            Category::Pooling => "Pooling",
        }
    }

    pub fn all() -> [Category; 7] {
        [
            Category::Activation,
            Category::Loss,
            Category::Math,
            Category::Normalization,
            Category::Optimizer,
            Category::Reduce,
            Category::Pooling,
        ]
    }

    /// Inverse of [`Category::name`] (report/journal deserialization).
    pub fn from_name(name: &str) -> Option<Category> {
        Category::all().into_iter().find(|c| c.name() == name)
    }
}

/// Scalar-to-scalar expression trees for element-wise computation. The
/// synthesizer lowers these to three-address DSL vector ops; the reference
/// evaluates them directly.
#[derive(Clone, Debug, PartialEq)]
pub enum OpExpr {
    /// i-th input tensor element.
    In(usize),
    Const(f64),
    Un(UnFn, Box<OpExpr>),
    Bin(BinFn, Box<OpExpr>, Box<OpExpr>),
    /// select(c, a, b): c >= 0 ? a : b
    SelectGe(Box<OpExpr>, Box<OpExpr>, Box<OpExpr>),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnFn {
    Exp,
    Log,
    Abs,
    Sqrt,
    Tanh,
    Neg,
    Recip,
    Relu,
    Sign,
    Floor,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinFn {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

impl OpExpr {
    pub fn input(i: usize) -> OpExpr {
        OpExpr::In(i)
    }
    pub fn c(v: f64) -> OpExpr {
        OpExpr::Const(v)
    }
    pub fn un(f: UnFn, a: OpExpr) -> OpExpr {
        OpExpr::Un(f, Box::new(a))
    }
    pub fn bin(f: BinFn, a: OpExpr, b: OpExpr) -> OpExpr {
        OpExpr::Bin(f, Box::new(a), Box::new(b))
    }
    pub fn add(a: OpExpr, b: OpExpr) -> OpExpr {
        OpExpr::bin(BinFn::Add, a, b)
    }
    pub fn sub(a: OpExpr, b: OpExpr) -> OpExpr {
        OpExpr::bin(BinFn::Sub, a, b)
    }
    pub fn mul(a: OpExpr, b: OpExpr) -> OpExpr {
        OpExpr::bin(BinFn::Mul, a, b)
    }
    pub fn div(a: OpExpr, b: OpExpr) -> OpExpr {
        OpExpr::bin(BinFn::Div, a, b)
    }

    /// Evaluate on one element vector (xs[i] = value of In(i)).
    pub fn eval(&self, xs: &[f32]) -> f32 {
        match self {
            OpExpr::In(i) => xs[*i],
            OpExpr::Const(v) => *v as f32,
            OpExpr::Un(f, a) => {
                let x = a.eval(xs);
                match f {
                    UnFn::Exp => x.exp(),
                    UnFn::Log => x.ln(),
                    UnFn::Abs => x.abs(),
                    UnFn::Sqrt => x.sqrt(),
                    UnFn::Tanh => x.tanh(),
                    UnFn::Neg => -x,
                    UnFn::Recip => 1.0 / x,
                    UnFn::Relu => x.max(0.0),
                    UnFn::Sign => {
                        if x > 0.0 {
                            1.0
                        } else if x < 0.0 {
                            -1.0
                        } else {
                            0.0
                        }
                    }
                    UnFn::Floor => x.floor(),
                }
            }
            OpExpr::Bin(f, a, b) => {
                let (x, y) = (a.eval(xs), b.eval(xs));
                match f {
                    BinFn::Add => x + y,
                    BinFn::Sub => x - y,
                    BinFn::Mul => x * y,
                    BinFn::Div => x / y,
                    BinFn::Max => x.max(y),
                    BinFn::Min => x.min(y),
                }
            }
            OpExpr::SelectGe(c, a, b) => {
                if c.eval(xs) >= 0.0 {
                    a.eval(xs)
                } else {
                    b.eval(xs)
                }
            }
        }
    }

    /// Vectorized evaluation: one tree walk with tight per-op loops over
    /// whole arrays (§Perf P4 — replaces per-element tree dispatch in the
    /// reference oracle, which the pipeline profile showed at ~10%).
    pub fn eval_bulk(&self, inputs: &[&[f32]]) -> Vec<f32> {
        let n = inputs.first().map(|s| s.len()).unwrap_or(0);
        match self {
            OpExpr::In(i) => inputs[*i].to_vec(),
            OpExpr::Const(v) => vec![*v as f32; n],
            OpExpr::Un(f, a) => {
                let mut x = a.eval_bulk(inputs);
                match f {
                    UnFn::Exp => x.iter_mut().for_each(|v| *v = v.exp()),
                    UnFn::Log => x.iter_mut().for_each(|v| *v = v.ln()),
                    UnFn::Abs => x.iter_mut().for_each(|v| *v = v.abs()),
                    UnFn::Sqrt => x.iter_mut().for_each(|v| *v = v.sqrt()),
                    UnFn::Tanh => x.iter_mut().for_each(|v| *v = v.tanh()),
                    UnFn::Neg => x.iter_mut().for_each(|v| *v = -*v),
                    UnFn::Recip => x.iter_mut().for_each(|v| *v = 1.0 / *v),
                    UnFn::Relu => x.iter_mut().for_each(|v| *v = v.max(0.0)),
                    UnFn::Sign => x.iter_mut().for_each(|v| {
                        *v = if *v > 0.0 {
                            1.0
                        } else if *v < 0.0 {
                            -1.0
                        } else {
                            0.0
                        }
                    }),
                    UnFn::Floor => x.iter_mut().for_each(|v| *v = v.floor()),
                }
                x
            }
            OpExpr::Bin(f, a, b) => {
                let mut x = a.eval_bulk(inputs);
                let y = b.eval_bulk(inputs);
                match f {
                    BinFn::Add => x.iter_mut().zip(&y).for_each(|(v, &w)| *v += w),
                    BinFn::Sub => x.iter_mut().zip(&y).for_each(|(v, &w)| *v -= w),
                    BinFn::Mul => x.iter_mut().zip(&y).for_each(|(v, &w)| *v *= w),
                    BinFn::Div => x.iter_mut().zip(&y).for_each(|(v, &w)| *v /= w),
                    BinFn::Max => x.iter_mut().zip(&y).for_each(|(v, &w)| *v = v.max(w)),
                    BinFn::Min => x.iter_mut().zip(&y).for_each(|(v, &w)| *v = v.min(w)),
                }
                x
            }
            OpExpr::SelectGe(c, a, b) => {
                let cv = c.eval_bulk(inputs);
                let mut av = a.eval_bulk(inputs);
                let bv = b.eval_bulk(inputs);
                for i in 0..av.len() {
                    if cv[i] < 0.0 {
                        av[i] = bv[i];
                    }
                }
                av
            }
        }
    }

    /// Number of non-leaf nodes — the op count a naive decomposition pays.
    pub fn op_count(&self) -> usize {
        match self {
            OpExpr::In(_) | OpExpr::Const(_) => 0,
            OpExpr::Un(_, a) => 1 + a.op_count(),
            OpExpr::Bin(_, a, b) => 1 + a.op_count() + b.op_count(),
            OpExpr::SelectGe(c, a, b) => 1 + c.op_count() + a.op_count() + b.op_count(),
        }
    }

    /// Highest input index referenced + 1.
    pub fn arity(&self) -> usize {
        match self {
            OpExpr::In(i) => i + 1,
            OpExpr::Const(_) => 0,
            OpExpr::Un(_, a) => a.arity(),
            OpExpr::Bin(_, a, b) => a.arity().max(b.arity()),
            OpExpr::SelectGe(c, a, b) => c.arity().max(a.arity()).max(b.arity()),
        }
    }
}

/// Loss function kinds (pointwise + mean reduction over all elements).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossKind {
    Mse,
    Mae,
    Huber,
    Bce,
    KlDiv,
    Hinge,
    /// Fused log-softmax cross-entropy over logits[N, C] and class targets.
    CrossEntropy,
}

/// Row-wise (last axis) reduction kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceOpKind {
    Sum,
    Max,
    Min,
    Mean,
    Prod,
}

/// Normalization kinds over [rows, cols] (normalize the last axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NormKind {
    Softmax,
    LogSoftmax,
    /// LayerNorm with learned gamma/beta (inputs 1, 2).
    LayerNorm,
    RmsNorm,
    /// Inference-mode batchnorm over [N, C] with per-column mean/var/γ/β.
    BatchNorm,
    /// Instance norm: same math as layernorm without affine params.
    InstanceNorm,
    GroupNorm { groups: usize },
    L2Norm,
}

/// Scan (prefix) kinds along the last axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanOpKind {
    Sum,
    Prod,
}

/// Pooling kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// What a task computes.
#[derive(Clone, Debug, PartialEq)]
pub enum ComputeSpec {
    /// out = expr(inputs) element-wise.
    Elementwise { expr: OpExpr },
    /// Scalar loss: pointwise expr over (pred, target) then mean.
    Loss { kind: LossKind },
    /// In-place state updates: out[i] <- expr_i(inputs) element-wise.
    /// Inputs are (param, grad, state...); each update is (index into
    /// `task.outputs`, expression over the *old* input state).
    Optimizer { updates: Vec<(usize, OpExpr)> },
    /// Reduce the last axis of input 0.
    Reduce { kind: ReduceOpKind },
    /// Normalize the last axis of input 0.
    Normalization { kind: NormKind },
    /// Prefix scan along the last axis; `masked` adds a bool mask input
    /// (elements where mask == 0 contribute identity).
    Scan { op: ScanOpKind, reverse: bool, masked: bool },
    /// Pooling. `dims` 1 or 2; window/stride in each spatial dim;
    /// input layout: 1D = [batch, length]; 2D = [batch, h, w]. `padding`
    /// pads each spatial edge (max: -inf; avg: excluded from the count,
    /// i.e. count_include_pad = False).
    Pooling { kind: PoolKind, window: usize, stride: usize, dims: usize, padding: usize },
    /// Composite row-wise math (logsumexp etc.) identified by name.
    RowComposite { kind: RowCompositeKind },
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowCompositeKind {
    LogSumExp,
    FrobeniusNorm,
}

/// One PyTorch-eager primitive launch: a tuned CANN kernel reading
/// `reads` and writing `writes` elements at `eff` × memory roofline.
#[derive(Clone, Debug, PartialEq)]
pub struct EagerOp {
    pub name: &'static str,
    pub reads: usize,
    pub writes: usize,
    /// Fraction of memory-bandwidth roofline this tuned kernel achieves.
    pub eff: f64,
}

impl EagerOp {
    pub fn map(name: &'static str, reads: usize, writes: usize) -> EagerOp {
        // tuned elementwise CANN kernels run very close to roofline
        EagerOp { name, reads, writes, eff: 0.95 }
    }
    pub fn with_eff(mut self, eff: f64) -> EagerOp {
        self.eff = eff;
        self
    }
}

/// A benchmark task.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    pub category: Category,
    /// Input tensors: (name, shape, dtype). Outputs are allocated zeroed.
    pub inputs: Vec<(&'static str, Vec<usize>, DType)>,
    pub outputs: Vec<(&'static str, Vec<usize>)>,
    pub compute: ComputeSpec,
    /// The eager-baseline decomposition (one tuned kernel per primitive
    /// PyTorch would dispatch on the NPU backend).
    pub eager: Vec<EagerOp>,
    /// Pass@1 comparison tolerances.
    pub rtol: f32,
    pub atol: f32,
}

impl TaskSpec {
    /// Deterministic random inputs (plus zeroed outputs) for this task.
    pub fn make_inputs(&self, seed: u64) -> HashMap<String, Tensor> {
        let mut rng = XorShiftRng::new(seed ^ fxhash(self.name));
        let mut m = HashMap::new();
        for (name, shape, dtype) in &self.inputs {
            let n: usize = shape.iter().product();
            let data = match (*dtype, self.category, *name) {
                (DType::Bool, _, _) => rng.mask_vec(n, 0.5),
                // probabilities for BCE/KL targets
                (_, Category::Loss, "target") if matches!(self.compute, ComputeSpec::Loss { kind: LossKind::Bce } | ComputeSpec::Loss { kind: LossKind::KlDiv }) => {
                    rng.uniform_vec(n, 0.05, 0.95)
                }
                (_, Category::Loss, "pred") if matches!(self.compute, ComputeSpec::Loss { kind: LossKind::Bce }) => {
                    rng.uniform_vec(n, 0.05, 0.95)
                }
                (_, Category::Loss, "pred") if matches!(self.compute, ComputeSpec::Loss { kind: LossKind::KlDiv }) => {
                    rng.uniform_vec(n, 0.05, 0.95)
                }
                // large-scale logits: kernels that skip the max-rescale
                // overflow exp() here (the cross_entropy Pass@1 failure)
                (_, Category::Loss, "pred") if matches!(self.compute, ComputeSpec::Loss { kind: LossKind::CrossEntropy }) => {
                    let mut v = rng.normal_vec(n);
                    v.iter_mut().for_each(|x| *x *= 30.0);
                    v
                }
                // class indices for cross-entropy targets
                (_, Category::Loss, "target") if matches!(self.compute, ComputeSpec::Loss { kind: LossKind::CrossEntropy }) => {
                    let classes = self.inputs[0].1[1];
                    (0..n).map(|_| rng.uniform_usize(0, classes) as f32).collect()
                }
                // strictly positive for log-domain ops (cumprod and the
                // prod reduction, whose expert kernel uses exp-sum-log)
                (_, _, _) if matches!(
                    self.compute,
                    ComputeSpec::Scan { op: ScanOpKind::Prod, .. }
                        | ComputeSpec::Reduce { kind: ReduceOpKind::Prod }
                ) => {
                    rng.uniform_vec(n, 0.9, 1.1)
                }
                // variance inputs must be positive
                (_, _, "var") => rng.uniform_vec(n, 0.5, 2.0),
                // second-moment / accumulator optimizer state is non-negative
                (_, Category::Optimizer, "v") | (_, Category::Optimizer, "s") => {
                    rng.uniform_vec(n, 0.0, 1.0)
                }
                (_, _, "gamma") => rng.uniform_vec(n, 0.5, 1.5),
                (_, _, "beta") => rng.uniform_vec(n, -0.5, 0.5),
                _ => rng.normal_vec(n),
            };
            m.insert(name.to_string(), Tensor::new(shape.clone(), *dtype, data));
        }
        for (name, shape) in &self.outputs {
            m.insert(name.to_string(), Tensor::zeros(shape));
        }
        m
    }

    /// Reference (oracle) outputs for the given inputs.
    pub fn reference(&self, tensors: &HashMap<String, Tensor>) -> HashMap<String, Tensor> {
        super::tasks::reference(self, tensors)
    }

    /// Total elements of the primary input.
    pub fn primary_numel(&self) -> usize {
        self.inputs[0].1.iter().product()
    }
}

/// Tiny deterministic string hash for per-task seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_names_round_trip() {
        for c in Category::all() {
            assert_eq!(Category::from_name(c.name()), Some(c));
        }
        assert_eq!(Category::from_name("Convolution"), None);
    }

    #[test]
    fn opexpr_eval_composites() {
        // sigmoid(x) = 1 / (1 + exp(-x))
        let sigmoid = OpExpr::div(
            OpExpr::c(1.0),
            OpExpr::add(OpExpr::c(1.0), OpExpr::un(UnFn::Exp, OpExpr::un(UnFn::Neg, OpExpr::input(0)))),
        );
        let x = 0.7f32;
        let want = 1.0 / (1.0 + (-x).exp());
        assert!((sigmoid.eval(&[x]) - want).abs() < 1e-6);
        assert_eq!(sigmoid.op_count(), 4);
        assert_eq!(sigmoid.arity(), 1);
    }

    #[test]
    fn selectge_semantics() {
        let e = OpExpr::SelectGe(
            Box::new(OpExpr::input(0)),
            Box::new(OpExpr::c(1.0)),
            Box::new(OpExpr::c(-1.0)),
        );
        assert_eq!(e.eval(&[0.5]), 1.0);
        assert_eq!(e.eval(&[0.0]), 1.0);
        assert_eq!(e.eval(&[-0.5]), -1.0);
    }

    #[test]
    fn make_inputs_is_deterministic() {
        let t = crate::bench_suite::tasks::all_tasks();
        let relu = t.iter().find(|t| t.name == "relu").unwrap();
        let a = relu.make_inputs(42);
        let b = relu.make_inputs(42);
        assert_eq!(a["x"], b["x"]);
        let c = relu.make_inputs(43);
        assert_ne!(a["x"], c["x"]);
    }

    #[test]
    fn outputs_are_zeroed() {
        let t = crate::bench_suite::tasks::all_tasks();
        let relu = t.iter().find(|t| t.name == "relu").unwrap();
        let m = relu.make_inputs(1);
        assert!(m["y"].data.iter().all(|&v| v == 0.0));
    }
}
