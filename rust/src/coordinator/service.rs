//! Codegen service: runs many kernel-generation jobs concurrently on the
//! shared persistent worker pool ([`crate::util::pool`]) and aggregates
//! suite results. This is the deployment shape of AscendCraft — a service
//! that takes kernel requests (task specs) and returns verified AscendC —
//! scaled down to std threads (tokio is not in the offline crate set;
//! generation jobs are CPU-bound anyway). Jobs claim work in index order
//! off one atomic counter, so a slow task never serializes the rest, and
//! nested parallelism (a job's own kernel/plan work) shares the same pool
//! without oversubscribing.

use super::journal::{self, Journal};
use super::pipeline::{run_task, PipelineArtifacts, PipelineConfig};
use super::stage::Session;
use crate::backend::Backend;
use crate::bench_suite::metrics::{GoldenStatus, SuiteResult, TaskResult};
use crate::bench_suite::spec::TaskSpec;
use crate::runtime::OracleRegistry;
use crate::util::compare::allclose_report;
use crate::util::json::Json;
use crate::util::pool;
use std::sync::{Arc, Mutex};

/// How the suite spreads its job list across workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Schedule {
    /// Work-stealing (the default): every executor claims the next
    /// unstarted job off one shared counter, so a slow task occupies one
    /// executor while the rest drain everything else.
    #[default]
    WorkSteal,
    /// Static round-robin shards (the pre-journal `run_suite_multi`
    /// behavior, kept as the scheduling ablation): worker `w` runs jobs
    /// `w, w+W, w+2W, …` serially, so a slow task delays everything
    /// behind it in its shard.
    StaticShard,
}

impl Schedule {
    /// Parse the CLI `--schedule` value.
    pub fn parse(name: &str) -> Option<Schedule> {
        match name {
            "steal" => Some(Schedule::WorkSteal),
            "static" => Some(Schedule::StaticShard),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Schedule::WorkSteal => "steal",
            Schedule::StaticShard => "static",
        }
    }
}

/// Run `f(idx)` for every job index under the chosen schedule, capped at
/// `workers` concurrent executors. Both schedules run every index exactly
/// once with the same per-index computation — scheduling decides *who*
/// runs an index and *when*, never *what* it computes — so results are
/// bit-identical across schedules and worker counts (the pool's
/// determinism contract). Resolves through the thread's current pool
/// ([`pool::run_parts_bounded`]) so tests can pin exact thread counts.
pub fn schedule_jobs(n: usize, workers: usize, schedule: Schedule, f: impl Fn(usize) + Sync) {
    if n == 0 {
        return;
    }
    let workers = workers.max(1);
    match schedule {
        Schedule::WorkSteal => pool::run_parts_bounded(n, workers, f),
        Schedule::StaticShard => {
            let shards = workers.min(n);
            pool::run_parts_bounded(shards, shards, |shard| {
                let mut idx = shard;
                while idx < n {
                    f(idx);
                    idx += shards;
                }
            });
        }
    }
}

/// Suite-run configuration.
#[derive(Clone)]
pub struct SuiteConfig {
    pub pipeline: PipelineConfig,
    pub workers: usize,
    /// Print one line per finished task.
    pub verbose: bool,
    /// When set, each worker cross-checks the task's Rust reference (L3)
    /// against the golden oracle (L2) from this registry right after the
    /// pipeline run, filling `TaskResult::golden`. The registry is shared:
    /// oracles load and compile once, then execute on every worker.
    pub golden: Option<Arc<OracleRegistry>>,
    /// Number of seeds the golden cross-check runs per task (seeds
    /// `pipeline.seed .. pipeline.seed + golden_seeds`). All seeds of a
    /// task execute through one [`crate::runtime::GoldenOracle::run_batch`]
    /// call, so the compiled plan and its scratch are shared across the
    /// whole batch. Per-seed outcomes land on `TaskResult::golden_seeds`;
    /// the aggregate stays on `TaskResult::golden`.
    pub golden_seeds: usize,
    /// Content-addressed result journal (`suite --journal/--resume`).
    /// Jobs whose tuple key has a durable record replay it instead of
    /// running the pipeline; completed jobs append theirs. Shared behind
    /// a mutex — workers touch it once per job (lookup is batched before
    /// the pool starts; appends are one lock each).
    pub journal: Option<Arc<Mutex<Journal>>>,
    /// Job scheduling policy (work-stealing by default).
    pub schedule: Schedule,
}

impl Default for SuiteConfig {
    fn default() -> SuiteConfig {
        SuiteConfig {
            pipeline: PipelineConfig::default(),
            workers: pool::configured_threads(),
            verbose: false,
            golden: None,
            golden_seeds: 1,
            journal: None,
            schedule: Schedule::WorkSteal,
        }
    }
}

/// Run a set of tasks on the worker pool; results come back in task order.
pub fn run_suite(tasks: &[TaskSpec], cfg: &SuiteConfig) -> SuiteResult {
    let artifacts = run_suite_artifacts(tasks, cfg);
    SuiteResult { results: artifacts.into_iter().map(|a| a.result).collect() }
}

/// One worker-pool job: a task, the pipeline configuration to run it
/// under (multi-backend runs clone the config per backend), and whether
/// this job carries the golden cross-check (backend-independent, so
/// multi-backend runs attach it to one backend's jobs only).
struct Job<'a> {
    task: &'a TaskSpec,
    pipeline: PipelineConfig,
    golden: bool,
}

/// Like [`run_suite`] but keeps the generated DSL/AscendC artifacts.
pub fn run_suite_artifacts(tasks: &[TaskSpec], cfg: &SuiteConfig) -> Vec<PipelineArtifacts> {
    let jobs: Vec<Job> = tasks
        .iter()
        .map(|task| Job { task, pipeline: cfg.pipeline.clone(), golden: true })
        .collect();
    run_jobs(&jobs, cfg, false)
}

/// Like [`run_suite`], but with an explicit pipeline configuration per
/// task (zipped positionally; the two slices must be the same length).
/// This is the `suite --tuned` entry point: the autotuner's best-config
/// store maps each task to its winning overrides, so tasks no longer
/// share one uniform `SuiteConfig::pipeline`. Everything else — golden
/// cross-checks, journaling, scheduling — behaves exactly like
/// [`run_suite`]; note the journal keys each job by *its own* pipeline
/// tuple, so tuned and untuned runs never share records.
pub fn run_suite_with_pipelines(
    tasks: &[TaskSpec],
    pipelines: &[PipelineConfig],
    cfg: &SuiteConfig,
) -> SuiteResult {
    assert_eq!(tasks.len(), pipelines.len(), "one pipeline config per task");
    let jobs: Vec<Job> = tasks
        .iter()
        .zip(pipelines)
        .map(|(task, pipeline)| Job { task, pipeline: pipeline.clone(), golden: true })
        .collect();
    let arts = run_jobs(&jobs, cfg, false);
    SuiteResult { results: arts.into_iter().map(|a| a.result).collect() }
}

/// Run one task list on several backends, sharded across **one** worker
/// pool: the job list is every (backend, task) pair, and idle workers
/// steal whichever job is next regardless of backend, so a slow backend
/// cannot serialize the run. Results come back grouped per backend, in
/// backend order, with task order preserved inside each group.
pub fn run_suite_multi(
    tasks: &[TaskSpec],
    cfg: &SuiteConfig,
    backends: &[Arc<dyn Backend>],
) -> MultiSuiteResult {
    let mut jobs: Vec<Job> = Vec::with_capacity(tasks.len() * backends.len());
    for (bi, backend) in backends.iter().enumerate() {
        for task in tasks {
            let mut pipeline = cfg.pipeline.clone();
            pipeline.backend = Arc::clone(backend);
            // the L2↔L3 golden cross-check is backend-independent (it
            // compares the oracle against the Rust reference, not against
            // a backend), so only the first backend's jobs pay for it;
            // the verdicts are copied to the other backends below
            jobs.push(Job { task, pipeline, golden: bi == 0 });
        }
    }
    let arts = run_jobs(&jobs, cfg, true);
    let mut per_backend: Vec<(String, SuiteResult)> = backends
        .iter()
        .enumerate()
        .map(|(bi, backend)| {
            let results = arts[bi * tasks.len()..(bi + 1) * tasks.len()]
                .iter()
                .map(|a| a.result.clone())
                .collect();
            (backend.name().to_string(), SuiteResult { results })
        })
        .collect();
    if cfg.golden.is_some() && per_backend.len() > 1 {
        let first: Vec<(Option<GoldenStatus>, Vec<GoldenStatus>)> = per_backend[0]
            .1
            .results
            .iter()
            .map(|r| (r.golden.clone(), r.golden_seeds.clone()))
            .collect();
        for (_, suite) in per_backend.iter_mut().skip(1) {
            for (r, (g, gs)) in suite.results.iter_mut().zip(&first) {
                r.golden = g.clone();
                r.golden_seeds = gs.clone();
            }
        }
    }
    MultiSuiteResult { per_backend }
}

/// The worker pool proper: drain an explicit (task, pipeline-config) job
/// list. Single-backend suite runs and multi-backend sharded runs are the
/// same pool with different job lists. `tag_backend` adds the backend
/// name to verbose progress lines (off for single-backend runs, whose
/// output stays byte-identical to the pre-registry suite).
fn run_jobs(jobs: &[Job], cfg: &SuiteConfig, tag_backend: bool) -> Vec<PipelineArtifacts> {
    let n = jobs.len();
    // Resolve journal keys and replayable hits up front under one lock:
    // workers then run lock-free until their own append. The key's golden
    // component counts the seeds a run would actually cross-check, so a
    // plain run and a --golden run never share a record.
    let cached: Vec<Option<(String, Option<TaskResult>)>> = match &cfg.journal {
        Some(shared) => {
            let mut jr = shared.lock().unwrap();
            jobs.iter()
                .map(|job| {
                    let seeds = if job.golden && cfg.golden.is_some() {
                        cfg.golden_seeds.max(1)
                    } else {
                        0
                    };
                    let key = journal::task_key(job.task, &job.pipeline, seeds);
                    let hit = jr.lookup(&key).cloned();
                    if hit.is_some() {
                        jr.note_hit();
                    }
                    Some((key, hit))
                })
                .collect()
        }
        None => (0..n).map(|_| None).collect(),
    };
    let slots: Vec<Mutex<Option<PipelineArtifacts>>> = (0..n).map(|_| Mutex::new(None)).collect();
    schedule_jobs(n, cfg.workers, cfg.schedule, |idx| {
        let job = &jobs[idx];
        let hit = cached[idx].as_ref().and_then(|(_, hit)| hit.as_ref());
        let replayed = hit.is_some();
        let art = match hit {
            // Journal hit: the key covers every semantic input, so the
            // recorded result stands in for a fresh pipeline run.
            Some(result) => PipelineArtifacts {
                result: result.clone(),
                session: Session::new(job.task, &job.pipeline),
            },
            None => {
                let mut art = run_task(job.task, &job.pipeline);
                if job.golden {
                    if let Some(reg) = &cfg.golden {
                        // the L2↔L3 cross-check shards across the same worker
                        // pool as the pipeline runs (the compiled, Send + Sync
                        // oracle is shared by all workers); all seeds of the
                        // task run through one batched oracle execution
                        let seeds: Vec<u64> = (0..cfg.golden_seeds.max(1) as u64)
                            .map(|k| job.pipeline.seed + k)
                            .collect();
                        let per_seed = cross_check_task_seeds(job.task, reg, &seeds);
                        art.result.golden = Some(summarize_golden(&per_seed));
                        art.result.golden_seeds = per_seed;
                    }
                }
                if let (Some((key, _)), Some(shared)) = (cached[idx].as_ref(), &cfg.journal) {
                    // a failed append must not fail the suite: the journal
                    // is a cache, the result is still in memory
                    if let Err(e) = shared.lock().unwrap().append(key, &art.result) {
                        eprintln!("warning: journal append failed: {e}");
                    }
                }
                art
            }
        };
        if cfg.verbose {
            let r = &art.result;
            let status = if r.correct {
                format!("pass  {:>7.2}x", r.speedup().unwrap_or(0.0))
            } else if r.compiled {
                "WRONG     ".to_string()
            } else {
                "NOCOMPILE ".to_string()
            };
            let golden_note = match &r.golden {
                Some(g) if g.checked && !g.ok => "  golden:FAIL",
                Some(g) if g.checked => "  golden:ok",
                _ => "",
            };
            // failures are structured: name the stage + code inline
            let fail_note = r
                .failure
                .as_ref()
                .map(|d| format!("  [{} {}]", d.stage, d.code))
                .unwrap_or_default();
            let backend_note =
                if tag_backend { format!("  @{}", r.backend) } else { String::new() };
            let cache_note = if replayed { "  (cached)" } else { "" };
            eprintln!(
                "[{:>2}/{n}] {:<18} {status}  ({} repairs, {:.2}s){golden_note}{fail_note}{backend_note}{cache_note}",
                idx + 1,
                r.name,
                r.repair_rounds,
                r.pipeline_secs
            );
        }
        *slots[idx].lock().unwrap() = Some(art);
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker dropped a task"))
        .collect()
}

/// Verdict agreement between two backends over the same task list.
#[derive(Clone, Debug)]
pub struct BackendAgreement {
    /// Tasks compared.
    pub total: usize,
    /// Tasks where both backends reached the same `correct` verdict.
    pub agree: usize,
    /// Tasks where verdicts differ: (task name, first backend's verdict,
    /// second backend's verdict).
    pub disagreements: Vec<(String, bool, bool)>,
}

/// Results of one task list sharded across several backends (see
/// [`run_suite_multi`]): per-backend [`SuiteResult`]s plus the
/// cross-backend comparison.
#[derive(Clone, Debug)]
pub struct MultiSuiteResult {
    /// One `(backend name, suite result)` per backend, in backend order;
    /// task order inside each suite matches the input task list.
    pub per_backend: Vec<(String, SuiteResult)>,
}

impl MultiSuiteResult {
    /// The suite result for one backend, by name.
    pub fn get(&self, backend: &str) -> Option<&SuiteResult> {
        self.per_backend.iter().find(|(name, _)| name == backend).map(|(_, suite)| suite)
    }

    /// Verdict agreement between two backends (by name). `None` when
    /// either backend is absent.
    pub fn agreement(&self, a: &str, b: &str) -> Option<BackendAgreement> {
        let (ra, rb) = (self.get(a)?, self.get(b)?);
        let mut agreement = BackendAgreement {
            total: ra.results.len().min(rb.results.len()),
            agree: 0,
            disagreements: Vec::new(),
        };
        for (x, y) in ra.results.iter().zip(&rb.results) {
            if x.correct == y.correct {
                agreement.agree += 1;
            } else {
                agreement.disagreements.push((x.name.clone(), x.correct, y.correct));
            }
        }
        Some(agreement)
    }

    /// Render the cross-backend comparison table: per-backend Comp@1 /
    /// Pass@1 / Fastₓ rates and pairwise verdict agreement (the
    /// sim-vs-cpu-ref consistency check).
    pub fn render_comparison(&self) -> String {
        let tasks = self.per_backend.first().map(|(_, r)| r.results.len()).unwrap_or(0);
        let mut s = String::new();
        s.push_str(&format!(
            "Cross-backend comparison ({} backends, {tasks} tasks each).\n",
            self.per_backend.len()
        ));
        s.push_str(&format!(
            "{:<14} {:>8} {:>8} {:>10} {:>10} {:>10}\n",
            "Backend", "Comp@1", "Pass@1", "Fast0.2@1", "Fast0.8@1", "Fast1.0@1"
        ));
        for (name, suite) in &self.per_backend {
            let t = suite.totals();
            // a backend without a timing model (every result lacks cycles)
            // has no Fastₓ story at all — render '-' rather than a 0.0
            // that reads as "measured and never fast"
            let timed = suite.results.iter().any(|r| r.generated_cycles.is_some());
            let fast = |pct: f64| {
                if timed {
                    format!("{pct:>10.1}")
                } else {
                    format!("{:>10}", "-")
                }
            };
            s.push_str(&format!(
                "{:<14} {:>8.1} {:>8.1} {} {} {}\n",
                name,
                t.comp_pct(),
                t.pass_pct(),
                fast(t.fast02_pct()),
                fast(t.fast08_pct()),
                fast(t.fast10_pct())
            ));
        }
        for i in 0..self.per_backend.len() {
            for j in i + 1..self.per_backend.len() {
                let (a, _) = &self.per_backend[i];
                let (b, _) = &self.per_backend[j];
                let ag = self.agreement(a, b).expect("both backends present");
                s.push_str(&format!(
                    "agreement {a} vs {b}: {}/{} tasks agree on correctness\n",
                    ag.agree, ag.total
                ));
                for (task, va, vb) in &ag.disagreements {
                    s.push_str(&format!("  {task:<18} {a}:{va} {b}:{vb}\n"));
                }
            }
        }
        s
    }

    /// JSON export: per-backend suite reports plus the pairwise agreement
    /// summaries.
    pub fn to_json(&self) -> Json {
        let mut backends = Json::obj();
        for (name, suite) in &self.per_backend {
            backends.set(name, suite.to_json());
        }
        let mut agreements = Json::Arr(vec![]);
        for i in 0..self.per_backend.len() {
            for j in i + 1..self.per_backend.len() {
                let (a, _) = &self.per_backend[i];
                let (b, _) = &self.per_backend[j];
                let ag = self.agreement(a, b).expect("both backends present");
                let mut entry = Json::obj();
                entry
                    .set("a", a.as_str())
                    .set("b", b.as_str())
                    .set("agree", ag.agree)
                    .set("total", ag.total);
                let mut dis = Json::Arr(vec![]);
                for (task, _, _) in &ag.disagreements {
                    dis.push(task.as_str());
                }
                entry.set("disagreements", dis);
                agreements.push(entry);
            }
        }
        let mut j = Json::obj();
        j.set("backends", backends).set("agreements", agreements);
        j
    }
}

/// Cross-check every task that has a golden artifact against the Rust
/// reference, in parallel on the worker pool, WITHOUT running the
/// generation pipeline. This is the standalone path behind
/// `ascendcraft oracle`; suite runs get the same check per task via
/// `SuiteConfig::golden` inside [`run_suite`]. The registry is shared by
/// all workers — the `Send + Sync` plan-backed oracle is what makes this
/// possible. Results come back in task order (zip with `tasks` for names).
pub fn cross_check_suite(
    tasks: &[TaskSpec],
    reg: &OracleRegistry,
    workers: usize,
    seed: u64,
) -> Vec<GoldenStatus> {
    let n = tasks.len();
    let slots: Vec<Mutex<Option<GoldenStatus>>> = (0..n).map(|_| Mutex::new(None)).collect();
    pool::global().run_bounded(n, workers.max(1), |idx| {
        *slots[idx].lock().unwrap() = Some(cross_check_task(&tasks[idx], reg, seed));
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("worker dropped a cross-check"))
        .collect()
}

/// Cross-check a single task against its golden artifact (if present).
/// The one shared implementation behind both the in-suite golden field
/// and the standalone `ascendcraft oracle` path.
pub fn cross_check_task(task: &TaskSpec, reg: &OracleRegistry, seed: u64) -> GoldenStatus {
    cross_check_task_seeds(task, reg, &[seed]).remove(0)
}

/// Multi-seed cross-check: the oracle's plan is compiled once (at registry
/// load), and all seeds execute through one
/// [`crate::runtime::GoldenOracle::run_batch`] call sharing a single plan
/// scratch — per-seed inputs are the only per-seed work. Returns one
/// [`GoldenStatus`] per seed, in seed order.
pub fn cross_check_task_seeds(
    task: &TaskSpec,
    reg: &OracleRegistry,
    seeds: &[u64],
) -> Vec<GoldenStatus> {
    let fail = |detail: String| GoldenStatus { checked: true, ok: false, detail };
    if !reg.available(task.name) {
        return seeds
            .iter()
            .map(|_| GoldenStatus { checked: false, ok: true, detail: "no artifact".to_string() })
            .collect();
    }
    let oracle = match reg.get(task.name) {
        Ok(o) => o,
        Err(e) => {
            let detail = format!("load failed: {e}");
            return seeds.iter().map(|_| fail(detail.clone())).collect();
        }
    };
    let per_seed_inputs: Vec<_> = seeds.iter().map(|&s| task.make_inputs(s)).collect();
    let batches: Vec<Vec<&crate::util::tensor::Tensor>> = per_seed_inputs
        .iter()
        .map(|inputs| task.inputs.iter().map(|(n, _, _)| &inputs[*n]).collect())
        .collect();
    // happy path: one batched execution for the whole seed set. If any
    // seed fails (execution errors can be data-dependent), re-run seed by
    // seed — still sharing one scratch — so a bad seed cannot mask the
    // verdicts of the good ones.
    let per_seed_outs: Vec<Result<Vec<crate::util::tensor::Tensor>, String>> =
        match oracle.run_batch(&batches) {
            Ok(outs) => outs.into_iter().map(Ok).collect(),
            Err(_) => {
                let mut scratch = crate::runtime::hlo::PlanScratch::default();
                batches
                    .iter()
                    .map(|b| {
                        oracle
                            .run_batch_with_scratch(std::slice::from_ref(b), &mut scratch)
                            .map(|mut v| v.remove(0))
                            .map_err(|e| e.to_string())
                    })
                    .collect()
            }
        };
    per_seed_inputs
        .iter()
        .zip(&per_seed_outs)
        .map(|(inputs, out)| {
            let got = match out {
                Ok(g) => g,
                Err(e) => return fail(format!("exec failed: {e}")),
            };
            let want = task.reference(inputs);
            if got.len() < task.outputs.len() {
                return fail(format!(
                    "oracle returned {} outputs, task has {}",
                    got.len(),
                    task.outputs.len()
                ));
            }
            // multi-output ops (adam) return tuples in task-output order
            for (i, (out_name, _)) in task.outputs.iter().enumerate() {
                let rep = allclose_report(&got[i], &want[*out_name], 2e-3, 2e-4);
                if !rep.ok {
                    return fail(format!("{out_name}: {}", rep.summary()));
                }
            }
            GoldenStatus { checked: true, ok: true, detail: "golden == rust reference".to_string() }
        })
        .collect()
}

/// Aggregate per-seed golden outcomes into the single `TaskResult::golden`
/// summary: checked if any seed checked, ok only if every seed passed.
pub fn summarize_golden(per_seed: &[GoldenStatus]) -> GoldenStatus {
    let checked = per_seed.iter().any(|g| g.checked);
    let failed: Vec<&GoldenStatus> = per_seed.iter().filter(|g| g.checked && !g.ok).collect();
    let detail = if let Some(f) = failed.first() {
        format!("{} of {} seeds failed; first: {}", failed.len(), per_seed.len(), f.detail)
    } else if checked {
        format!("golden == rust reference ({} seeds)", per_seed.len())
    } else {
        "no artifact".to_string()
    };
    GoldenStatus { checked, ok: failed.is_empty(), detail }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::tasks::task_by_name;

    #[test]
    fn run_suite_handles_empty_task_list() {
        let suite = run_suite(&[], &SuiteConfig::default());
        assert!(suite.results.is_empty());
    }

    #[test]
    fn run_suite_with_more_workers_than_tasks_does_not_hang() {
        let tasks: Vec<_> = [task_by_name("relu").unwrap()].to_vec();
        let cfg = SuiteConfig { workers: 32, ..Default::default() };
        let suite = run_suite(&tasks, &cfg);
        assert_eq!(suite.results.len(), 1);
        assert!(suite.results[0].correct);
    }

    #[test]
    fn run_suite_with_golden_fills_task_results() {
        let tasks: Vec<_> =
            ["relu", "softsign"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let cfg = SuiteConfig {
            workers: 2,
            golden: Some(Arc::new(OracleRegistry::default_dir())),
            ..Default::default()
        };
        let suite = run_suite(&tasks, &cfg);
        // relu has a checked-in artifact; softsign does not (vacuous pass)
        let relu = &suite.results[0];
        let g = relu.golden.as_ref().expect("golden ran in-suite");
        assert!(g.checked && g.ok, "relu golden: {}", g.detail);
        let softsign = &suite.results[1];
        let g = softsign.golden.as_ref().expect("golden ran in-suite");
        assert!(!g.checked && g.ok, "softsign golden: {}", g.detail);
        assert_eq!(suite.golden_checked(), 1);
        assert!(suite.golden_failures().is_empty());
    }

    #[test]
    fn run_suite_without_golden_leaves_results_unset() {
        let tasks = [task_by_name("relu").unwrap()];
        let suite = run_suite(&tasks, &SuiteConfig::default());
        assert!(suite.results[0].golden.is_none());
    }

    #[test]
    fn cross_check_runs_in_parallel_against_fixtures() {
        let reg = OracleRegistry::default_dir();
        let tasks: Vec<_> = ["relu", "sigmoid", "tanh_act", "softmax"]
            .iter()
            .map(|n| task_by_name(n).unwrap())
            .collect();
        let checks = cross_check_suite(&tasks, &reg, 4, 4242);
        assert_eq!(checks.len(), 4);
        for (t, c) in tasks.iter().zip(&checks) {
            assert!(c.checked, "{}: artifact missing", t.name);
            assert!(c.ok, "{}: {}", t.name, c.detail);
        }
    }

    #[test]
    fn cross_check_task_seeds_matches_per_seed_checks() {
        let reg = OracleRegistry::default_dir();
        let task = task_by_name("softmax").unwrap();
        let seeds = [11u64, 12, 13];
        let batched = cross_check_task_seeds(&task, &reg, &seeds);
        assert_eq!(batched.len(), 3);
        for (&s, b) in seeds.iter().zip(&batched) {
            let single = cross_check_task(&task, &reg, s);
            assert_eq!(single.checked, b.checked, "seed {s}");
            assert_eq!(single.ok, b.ok, "seed {s}: {}", b.detail);
        }
    }

    #[test]
    fn run_suite_with_golden_seeds_records_per_seed_statuses() {
        let tasks = [task_by_name("relu").unwrap()];
        let cfg = SuiteConfig {
            workers: 1,
            golden: Some(Arc::new(OracleRegistry::default_dir())),
            golden_seeds: 3,
            ..Default::default()
        };
        let suite = run_suite(&tasks, &cfg);
        let r = &suite.results[0];
        assert_eq!(r.golden_seeds.len(), 3);
        assert!(r.golden_seeds.iter().all(|g| g.checked && g.ok));
        let agg = r.golden.as_ref().unwrap();
        assert!(agg.checked && agg.ok, "{}", agg.detail);
        assert!(agg.detail.contains("3 seeds"), "{}", agg.detail);
    }

    #[test]
    fn summarize_golden_aggregates_failures() {
        let ok = GoldenStatus { checked: true, ok: true, detail: "ok".into() };
        let bad = GoldenStatus { checked: true, ok: false, detail: "drift".into() };
        let vac = GoldenStatus { checked: false, ok: true, detail: "no artifact".into() };
        let s = summarize_golden(&[ok.clone(), bad, ok]);
        assert!(s.checked && !s.ok);
        assert!(s.detail.contains("1 of 3"), "{}", s.detail);
        let s = summarize_golden(&[vac.clone(), vac]);
        assert!(!s.checked && s.ok);
    }

    #[test]
    fn cross_check_is_vacuous_without_artifact() {
        let reg = OracleRegistry::new("/nonexistent/dir");
        let task = task_by_name("relu").unwrap();
        let c = cross_check_task(&task, &reg, 1);
        assert!(!c.checked);
        assert!(c.ok);
    }

    #[test]
    fn cross_check_empty_task_list() {
        let reg = OracleRegistry::default_dir();
        assert!(cross_check_suite(&[], &reg, 8, 1).is_empty());
    }

    #[test]
    fn suite_runs_in_parallel_and_preserves_order() {
        let tasks: Vec<_> = ["relu", "tanh_act", "softsign", "relu6"]
            .iter()
            .map(|n| task_by_name(n).unwrap())
            .collect();
        let cfg = SuiteConfig { workers: 4, ..Default::default() };
        let suite = run_suite(&tasks, &cfg);
        assert_eq!(suite.results.len(), 4);
        for (t, r) in tasks.iter().zip(&suite.results) {
            assert_eq!(t.name, r.name);
            assert!(r.correct, "{}: {:?}", r.name, r.failure);
        }
    }

    #[test]
    fn run_suite_multi_shards_one_pool_across_backends() {
        use crate::backend::BackendRegistry;
        let tasks: Vec<_> =
            ["relu", "softsign"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let cfg = SuiteConfig { workers: 4, verbose: false, ..Default::default() };
        let multi = run_suite_multi(&tasks, &cfg, &BackendRegistry::builtin().all());
        assert_eq!(multi.per_backend.len(), 2);
        assert_eq!(multi.per_backend[0].0, "ascend-sim");
        assert_eq!(multi.per_backend[1].0, "cpu-ref");
        for (backend, suite) in &multi.per_backend {
            assert_eq!(suite.results.len(), tasks.len(), "{backend}");
            for (t, r) in tasks.iter().zip(&suite.results) {
                assert_eq!(t.name, r.name, "{backend}: task order preserved");
                assert_eq!(&r.backend, backend, "result records its backend");
                assert!(r.correct, "{backend}/{}: {:?}", r.name, r.failure);
            }
        }
        // the timing model is an ascend-sim concern: cpu-ref has no cycles
        let sim = multi.get("ascend-sim").unwrap();
        assert!(sim.results.iter().all(|r| r.generated_cycles.is_some()));
        let cpu = multi.get("cpu-ref").unwrap();
        assert!(cpu.results.iter().all(|r| r.generated_cycles.is_none()));
    }

    #[test]
    fn multi_suite_comparison_reports_rates_and_agreement() {
        use crate::backend::BackendRegistry;
        let tasks: Vec<_> = ["relu", "gelu"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let cfg = SuiteConfig { workers: 2, verbose: false, ..Default::default() };
        let multi = run_suite_multi(&tasks, &cfg, &BackendRegistry::builtin().all());
        let ag = multi.agreement("ascend-sim", "cpu-ref").unwrap();
        assert_eq!((ag.agree, ag.total), (2, 2));
        assert!(ag.disagreements.is_empty());
        let table = multi.render_comparison();
        assert!(table.contains("ascend-sim"), "{table}");
        assert!(table.contains("cpu-ref"), "{table}");
        assert!(table.contains("2/2 tasks agree"), "{table}");
        // the timing-less backend renders '-' for all three Fastₓ columns
        // (not a 0.0 that reads as "measured and never fast")
        let cpu_line = table.lines().find(|l| l.starts_with("cpu-ref")).unwrap();
        assert_eq!(cpu_line.matches(" -").count(), 3, "{table}");
        let sim_line = table.lines().find(|l| l.starts_with("ascend-sim")).unwrap();
        assert_eq!(sim_line.matches(" -").count(), 0, "{table}");
        let json = multi.to_json().to_string();
        assert!(json.contains("\"backends\""), "{json}");
        assert!(json.contains("\"agreements\""), "{json}");
        // round-trips through the hand-rolled parser
        assert!(crate::util::json::Json::parse(&json).is_ok());
    }

    #[test]
    fn single_worker_matches_parallel() {
        let tasks: Vec<_> =
            ["relu", "sigmoid"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let a = run_suite(&tasks, &SuiteConfig { workers: 1, ..Default::default() });
        let b = run_suite(&tasks, &SuiteConfig { workers: 2, ..Default::default() });
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.generated_cycles, y.generated_cycles);
        }
    }

    #[test]
    fn run_suite_with_pipelines_applies_per_task_configs() {
        let tasks: Vec<_> = ["relu", "sigmoid"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let base = PipelineConfig::default();
        let mut tuned = base.clone();
        tuned.options.tiling_overrides = vec![("tile_len".to_string(), 1024)];
        let uniform = run_suite(&tasks, &SuiteConfig::default());
        let mixed = run_suite_with_pipelines(
            &tasks,
            &[base, tuned],
            &SuiteConfig { workers: 2, ..Default::default() },
        );
        assert_eq!(mixed.results.len(), 2);
        for (t, r) in tasks.iter().zip(&mixed.results) {
            assert_eq!(t.name, r.name);
            assert!(r.correct, "{}: {:?}", r.name, r.failure);
        }
        // task 0 ran the untouched base config: identical to the uniform run
        assert_eq!(mixed.results[0].generated_cycles, uniform.results[0].generated_cycles);
        // task 1 ran a different tiling: the simulated cost must differ
        assert_ne!(mixed.results[1].generated_cycles, uniform.results[1].generated_cycles);
    }

    #[test]
    fn schedule_parse_round_trips() {
        assert_eq!(Schedule::parse("steal"), Some(Schedule::WorkSteal));
        assert_eq!(Schedule::parse("static"), Some(Schedule::StaticShard));
        assert_eq!(Schedule::parse("dynamic"), None);
        for s in [Schedule::WorkSteal, Schedule::StaticShard] {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::default(), Schedule::WorkSteal);
    }

    #[test]
    fn schedule_jobs_runs_every_index_exactly_once_under_both_schedules() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for schedule in [Schedule::WorkSteal, Schedule::StaticShard] {
            for workers in [1usize, 2, 8, 64] {
                let n = 23;
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                schedule_jobs(n, workers, schedule, |idx| {
                    counts[idx].fetch_add(1, Ordering::SeqCst);
                });
                for (idx, c) in counts.iter().enumerate() {
                    assert_eq!(
                        c.load(Ordering::SeqCst),
                        1,
                        "{schedule:?} workers={workers} idx={idx}"
                    );
                }
            }
        }
        // n == 0 must not hang or panic
        schedule_jobs(0, 4, Schedule::WorkSteal, |_| unreachable!());
        schedule_jobs(0, 4, Schedule::StaticShard, |_| unreachable!());
    }

    #[test]
    fn static_shard_schedule_matches_work_steal_results() {
        let tasks: Vec<_> =
            ["relu", "softsign"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let steal = run_suite(
            &tasks,
            &SuiteConfig { workers: 2, schedule: Schedule::WorkSteal, ..Default::default() },
        );
        let shard = run_suite(
            &tasks,
            &SuiteConfig { workers: 2, schedule: Schedule::StaticShard, ..Default::default() },
        );
        assert_eq!(steal.canonical(), shard.canonical());
    }

    #[test]
    fn journaled_suite_replays_cached_results() {
        let dir = std::env::temp_dir().join(format!("ac-svc-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("suite.jsonl");
        let _ = std::fs::remove_file(&path);
        let tasks: Vec<_> =
            ["relu", "sigmoid"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let journal = Arc::new(Mutex::new(Journal::open(&path, false).unwrap()));
        let cfg = SuiteConfig {
            workers: 2,
            journal: Some(Arc::clone(&journal)),
            ..Default::default()
        };
        let first = run_suite(&tasks, &cfg);
        assert_eq!(journal.lock().unwrap().stats(), (0, 2));

        // a second run over the same journal replays everything; results
        // are identical to the first run byte for byte (clocks included,
        // because the replay *is* the first run's record)
        let journal2 = Arc::new(Mutex::new(Journal::open(&path, false).unwrap()));
        let cfg2 = SuiteConfig {
            workers: 2,
            journal: Some(Arc::clone(&journal2)),
            ..Default::default()
        };
        let second = run_suite(&tasks, &cfg2);
        assert_eq!(journal2.lock().unwrap().stats(), (2, 0));
        assert_eq!(first, second);

        // a config change (different seed) misses the cache entirely
        let journal3 = Arc::new(Mutex::new(Journal::open(&path, false).unwrap()));
        let cfg3 = SuiteConfig {
            pipeline: PipelineConfig { seed: 99, ..Default::default() },
            workers: 2,
            journal: Some(Arc::clone(&journal3)),
            ..Default::default()
        };
        let _ = run_suite(&tasks, &cfg3);
        assert_eq!(journal3.lock().unwrap().stats(), (0, 2));
        let _ = std::fs::remove_file(&path);
    }
}
