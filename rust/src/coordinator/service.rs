//! Codegen service: a worker pool that runs many kernel-generation jobs
//! concurrently and aggregates suite results. This is the deployment shape
//! of AscendCraft — a service that takes kernel requests (task specs) and
//! returns verified AscendC — scaled down to std threads (tokio is not in
//! the offline crate set; generation jobs are CPU-bound anyway).

use super::pipeline::{run_task, PipelineArtifacts, PipelineConfig};
use crate::bench_suite::metrics::SuiteResult;
use crate::bench_suite::spec::TaskSpec;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Suite-run configuration.
#[derive(Clone, Debug)]
pub struct SuiteConfig {
    pub pipeline: PipelineConfig,
    pub workers: usize,
    /// Print one line per finished task.
    pub verbose: bool,
}

impl Default for SuiteConfig {
    fn default() -> SuiteConfig {
        SuiteConfig {
            pipeline: PipelineConfig::default(),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            verbose: false,
        }
    }
}

/// Run a set of tasks on the worker pool; results come back in task order.
pub fn run_suite(tasks: &[TaskSpec], cfg: &SuiteConfig) -> SuiteResult {
    let artifacts = run_suite_artifacts(tasks, cfg);
    SuiteResult { results: artifacts.into_iter().map(|a| a.result).collect() }
}

/// Like [`run_suite`] but keeps the generated DSL/AscendC artifacts.
pub fn run_suite_artifacts(tasks: &[TaskSpec], cfg: &SuiteConfig) -> Vec<PipelineArtifacts> {
    let n = tasks.len();
    let next = Arc::new(Mutex::new(0usize));
    let (tx, rx) = mpsc::channel::<(usize, PipelineArtifacts)>();

    std::thread::scope(|scope| {
        for _ in 0..cfg.workers.max(1).min(n.max(1)) {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let pipeline = cfg.pipeline.clone();
            let verbose = cfg.verbose;
            scope.spawn(move || loop {
                let idx = {
                    let mut guard = next.lock().unwrap();
                    if *guard >= n {
                        return;
                    }
                    let i = *guard;
                    *guard += 1;
                    i
                };
                let art = run_task(&tasks[idx], &pipeline);
                if verbose {
                    let r = &art.result;
                    let status = if r.correct {
                        format!("pass  {:>7.2}x", r.speedup().unwrap_or(0.0))
                    } else if r.compiled {
                        "WRONG     ".to_string()
                    } else {
                        "NOCOMPILE ".to_string()
                    };
                    eprintln!(
                        "[{:>2}/{n}] {:<18} {status}  ({} repairs, {:.2}s)",
                        idx + 1,
                        r.name,
                        r.repair_rounds,
                        r.pipeline_secs
                    );
                }
                let _ = tx.send((idx, art));
            });
        }
        drop(tx);
        let mut out: Vec<Option<PipelineArtifacts>> = (0..n).map(|_| None).collect();
        for (idx, art) in rx {
            out[idx] = Some(art);
        }
        out.into_iter().map(|a| a.expect("worker dropped a task")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::tasks::task_by_name;

    #[test]
    fn suite_runs_in_parallel_and_preserves_order() {
        let tasks: Vec<_> = ["relu", "tanh_act", "softsign", "relu6"]
            .iter()
            .map(|n| task_by_name(n).unwrap())
            .collect();
        let cfg = SuiteConfig { workers: 4, ..Default::default() };
        let suite = run_suite(&tasks, &cfg);
        assert_eq!(suite.results.len(), 4);
        for (t, r) in tasks.iter().zip(&suite.results) {
            assert_eq!(t.name, r.name);
            assert!(r.correct, "{}: {:?}", r.name, r.failure);
        }
    }

    #[test]
    fn single_worker_matches_parallel() {
        let tasks: Vec<_> =
            ["relu", "sigmoid"].iter().map(|n| task_by_name(n).unwrap()).collect();
        let a = run_suite(&tasks, &SuiteConfig { workers: 1, ..Default::default() });
        let b = run_suite(&tasks, &SuiteConfig { workers: 2, ..Default::default() });
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.correct, y.correct);
            assert_eq!(x.generated_cycles, y.generated_cycles);
        }
    }
}
