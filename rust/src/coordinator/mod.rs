//! L3 coordinator: the AscendCraft code-generation service.
//!
//! * [`stage`] — the staged compilation-session API: typed [`stage::Stage`]s
//!   (generate → frontend → transpile/repair → compile → simulate → score)
//!   accumulating artifacts on a [`stage::Session`], with per-stage
//!   [`stage::StageReport`] timings and structured [`stage::Diagnostic`]s.
//! * [`pipeline`] — the thin per-task driver over the stage list, plus the
//!   [`pipeline::PipelineConfig`] whose ablation knobs select stage
//!   configurations.
//! * [`service`] — a std-thread worker pool that runs many tasks
//!   concurrently (the deployment shape: a codegen service consuming kernel
//!   requests and emitting verified AscendC), plus suite runners for the
//!   benchmark tables. [`service::run_suite_multi`] spreads one
//!   (backend, task) job list across the pool via the work-stealing
//!   scheduler ([`service::schedule_jobs`]) and reports a cross-backend
//!   comparison.
//! * [`journal`] — the content-addressed result journal behind
//!   `suite --journal/--resume`: incremental re-runs skip tuples with a
//!   durable record; interrupted runs resume from the last one.
//!
//! Python never appears on this path; the JAX golden oracle in `runtime`
//! (HLO text executed by the built-in interpreter) is a cross-check
//! loaded from the checked-in artifacts — see [`service::cross_check_suite`].

pub mod journal;
pub mod pipeline;
pub mod service;
pub mod stage;

pub use journal::Journal;
pub use pipeline::{run_task, PipelineConfig, PipelineMode};
pub use service::{
    run_suite, run_suite_multi, run_suite_with_pipelines, MultiSuiteResult, Schedule, SuiteConfig,
};
pub use stage::{Diagnostic, Session, Stage, StageOutcome, StageReport};
