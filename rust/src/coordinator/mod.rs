//! L3 coordinator: the AscendCraft code-generation service.
//!
//! * [`pipeline`] — the end-to-end per-task driver: DSL generation →
//!   frontend validation → four transcompilation passes with the per-pass
//!   compile-feedback repair loop → NPU simulation → Pass@1/Fastₓ scoring.
//! * [`service`] — a std-thread worker pool that runs many tasks
//!   concurrently (the deployment shape: a codegen service consuming kernel
//!   requests and emitting verified AscendC), plus suite runners for the
//!   benchmark tables.
//!
//! Python never appears on this path; the JAX golden oracle in `runtime`
//! (HLO text executed by the built-in interpreter) is a cross-check
//! loaded from the checked-in artifacts — see [`service::cross_check_suite`].

pub mod pipeline;
pub mod service;

pub use pipeline::{run_task, PipelineConfig, PipelineMode};
pub use service::{run_suite, SuiteConfig};
