//! The staged compilation-session API: the paper's Figure 3 flow as a
//! first-class, inspectable pipeline instead of one monolithic function.
//!
//! ```text
//! generate ──► frontend ──► transpile (repair combinator) ──► analyze ──► compile
//!                                                                          │
//!                                   score ◄── simulate ◄─────────────────┘
//! ```
//!
//! Each box is a [`Stage`]: a named unit that reads and writes typed
//! artifacts on a [`Session`] (DSL source, validated [`DslProgram`],
//! [`AscProgram`], [`CompiledKernel`], [`ExecOutput`], …). The driver in
//! [`super::pipeline::run_task`] walks a stage list selected from the
//! [`PipelineConfig`] (ablations pick different lists, not different code
//! paths), records a [`StageReport`] with wall time and outcome per
//! executed stage, and stops at the first failure.
//!
//! The compile and simulate boxes are *backend-mediated*: they call the
//! configured [`crate::backend::Backend`] (`PipelineConfig::backend`)
//! instead of reaching into `ascendc::validate`/`sim::exec` directly, so
//! alternative targets (the CPU-reference backend, future hardware
//! backends) plug in without touching the stage driver.
//!
//! Failures are structured [`Diagnostic`]s — stage name, stable code,
//! message, optional DSL line — never ad-hoc strings. Every error type in
//! the pipeline ([`GenError`], [`DslDiagnostic`], [`TranspileError`],
//! [`AscDiagnostic`], [`SimError`]) converts into `Diagnostic` via `From`,
//! so `TaskResult::failure` is machine-readable end to end (it serializes
//! in `TaskResult::to_json` and round-trips through
//! [`crate::util::json::Json::parse`]).

use super::pipeline::{PipelineArtifacts, PipelineConfig, PipelineMode};
use crate::ascendc::validate::AscDiagnostic;
use crate::ascendc::AscProgram;
use crate::backend::{Backend as _, CompiledKernel, ExecOutput};
use crate::bench_suite::metrics::TaskResult;
use crate::bench_suite::spec::TaskSpec;
use crate::dsl::{self, DslDiagnostic, DslProgram};
use crate::sim::SimError;
use crate::synth::{self, direct::DirectGenerator, repair, GenError, GenResult, Generator};
use crate::transpile::{self, TranspileError, TranspileOptions};
use crate::util::compare::allclose_report;
use crate::util::json::Json;
use crate::util::tensor::Tensor;
use std::collections::HashMap;
use std::fmt;
use std::time::Instant;

/// Canonical stage names, in paper-Figure-3 order. `StageReport::name` and
/// `Diagnostic::stage` always hold one of these.
pub const STAGE_GENERATE: &str = "generate";
pub const STAGE_FRONTEND: &str = "frontend";
pub const STAGE_TRANSPILE: &str = "transpile";
pub const STAGE_ANALYZE: &str = "analyze";
pub const STAGE_COMPILE: &str = "compile";
pub const STAGE_SIMULATE: &str = "simulate";
pub const STAGE_SCORE: &str = "score";

/// Version of the stage-list semantics. Bump this whenever a stage's
/// *behavior* changes in a way that invalidates previously recorded
/// results without changing the stage names (the names themselves are
/// part of [`stage_list_fingerprint`] already). The fingerprint feeds
/// the suite journal's content-address
/// ([`crate::coordinator::journal::task_key`]), so bumping it makes
/// every journaled result a miss — exactly what a semantic change needs.
pub const STAGE_LIST_VERSION: &str = "v1";

/// The pipeline-version component of the journal key: the stage-list
/// semantic version plus the ordered stage names the configuration
/// selects, e.g.
/// `v1:generate>frontend>transpile>analyze>compile>simulate>score`
/// (or the four-stage direct-mode list). Adding, removing, or reordering
/// stages changes this string and therefore every journal key.
pub fn stage_list_fingerprint(cfg: &PipelineConfig) -> String {
    let names: Vec<&str> = stage_list(cfg).iter().map(|s| s.name()).collect();
    format!("{STAGE_LIST_VERSION}:{}", names.join(">"))
}

/// Map a parsed stage name back to its canonical `&'static str` constant
/// (the `STAGE_*` family). `StageReport::name` is `&'static str`, so
/// deserialization must intern through here; unknown names are rejected.
pub fn canonical_stage_name(name: &str) -> Option<&'static str> {
    match name {
        STAGE_GENERATE => Some(STAGE_GENERATE),
        STAGE_FRONTEND => Some(STAGE_FRONTEND),
        STAGE_TRANSPILE => Some(STAGE_TRANSPILE),
        STAGE_ANALYZE => Some(STAGE_ANALYZE),
        STAGE_COMPILE => Some(STAGE_COMPILE),
        STAGE_SIMULATE => Some(STAGE_SIMULATE),
        STAGE_SCORE => Some(STAGE_SCORE),
        _ => None,
    }
}

/// A structured pipeline diagnostic: which stage produced it, a stable
/// machine-readable code (the validator/repair-engine code families:
/// `G…` generation, `P…`/`D…` DSL frontend, `H…` host lowering, `A…`
/// AscendC validation, `S…` simulation, `N…` numeric scoring), a human
/// message, and the 1-based DSL source line when known.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub stage: String,
    pub code: String,
    pub message: String,
    /// 1-based DSL source line, for frontend-level diagnostics.
    pub line: Option<usize>,
}

impl Diagnostic {
    pub fn new(stage: &str, code: &str, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            stage: stage.to_string(),
            code: code.to_string(),
            message: message.into(),
            line: None,
        }
    }

    pub fn with_line(mut self, line: usize) -> Diagnostic {
        self.line = Some(line);
        self
    }

    /// A driver-level invariant violation (a stage ran without its input
    /// artifact). Code `X000` — these indicate bugs, not task failures.
    pub fn internal(stage: &str, message: impl Into<String>) -> Diagnostic {
        Diagnostic::new(stage, "X000", message)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("stage", self.stage.as_str())
            .set("code", self.code.as_str())
            .set("message", self.message.as_str());
        if let Some(line) = self.line {
            j.set("line", line);
        }
        j
    }

    /// Inverse of [`Diagnostic::to_json`] (used by report consumers and the
    /// round-trip tests). Returns `None` on a malformed object.
    pub fn from_json(j: &Json) -> Option<Diagnostic> {
        Some(Diagnostic {
            stage: j.get("stage")?.as_str()?.to_string(),
            code: j.get("code")?.as_str()?.to_string(),
            message: j.get("message")?.as_str()?.to_string(),
            line: j.get("line").and_then(Json::as_f64).map(|l| l as usize),
        })
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{} {}] {}", self.stage, self.code, self.message)?;
        if let Some(line) = self.line {
            write!(f, " (DSL line {line})")?;
        }
        Ok(())
    }
}

impl From<GenError> for Diagnostic {
    fn from(e: GenError) -> Diagnostic {
        Diagnostic::new(STAGE_GENERATE, &e.code, e.message)
    }
}

impl From<DslDiagnostic> for Diagnostic {
    fn from(d: DslDiagnostic) -> Diagnostic {
        Diagnostic::new(STAGE_FRONTEND, &d.code, d.message).with_line(d.line)
    }
}

impl From<TranspileError> for Diagnostic {
    fn from(e: TranspileError) -> Diagnostic {
        Diagnostic::new(STAGE_TRANSPILE, &e.code, format!("{} ({})", e.message, e.pass))
    }
}

impl From<AscDiagnostic> for Diagnostic {
    fn from(d: AscDiagnostic) -> Diagnostic {
        let mut message = d.message;
        if !d.kernel.is_empty() {
            message.push_str(&format!(" [kernel {}", d.kernel));
            let loc = d.location();
            if !loc.is_empty() {
                message.push_str(&format!(", {loc}"));
            }
            message.push(']');
        }
        let mut out = Diagnostic::new(STAGE_COMPILE, &d.code, message);
        out.line = d.dsl_line;
        out
    }
}

impl From<SimError> for Diagnostic {
    fn from(e: SimError) -> Diagnostic {
        let code = match &e {
            SimError::Host(_) => "S101",
            SimError::Kernel(_) => "S102",
            SimError::Oob(_) => "S103",
            SimError::StepLimit => "S104",
        };
        Diagnostic::new(STAGE_SIMULATE, code, e.to_string())
    }
}

/// Did a stage complete?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageOutcome {
    Ok,
    Failed,
}

impl StageOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            StageOutcome::Ok => "ok",
            StageOutcome::Failed => "failed",
        }
    }

    /// Inverse of [`StageOutcome::name`].
    pub fn from_name(name: &str) -> Option<StageOutcome> {
        match name {
            "ok" => Some(StageOutcome::Ok),
            "failed" => Some(StageOutcome::Failed),
            _ => None,
        }
    }
}

/// One executed stage: its canonical name, wall-clock seconds, and outcome.
/// The session's report list *is* `TaskResult::stage_timings`.
#[derive(Clone, Debug, PartialEq)]
pub struct StageReport {
    pub name: &'static str,
    pub wall_secs: f64,
    pub outcome: StageOutcome,
}

impl StageReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", self.name).set("secs", self.wall_secs).set("outcome", self.outcome.name());
        j
    }

    /// Inverse of [`StageReport::to_json`] (the suite journal replays
    /// recorded results through here). Returns `None` on a malformed
    /// object or a non-canonical stage name.
    pub fn from_json(j: &Json) -> Option<StageReport> {
        Some(StageReport {
            name: canonical_stage_name(j.get("name")?.as_str()?)?,
            wall_secs: j.get("secs")?.as_f64()?,
            outcome: StageOutcome::from_name(j.get("outcome")?.as_str()?)?,
        })
    }
}

/// Everything one task accumulates as it moves through the stage list:
/// the typed intermediate artifacts, the per-stage reports, and every
/// structured diagnostic (fatal or not). `PipelineArtifacts` exposes the
/// whole session, which is what `ascendcraft compile --emit=…` dumps.
#[derive(Clone, Debug)]
pub struct Session {
    /// Task input tensors (plus generator scratch buffers). Consumed —
    /// moved into the simulator — by the simulate stage.
    pub inputs: HashMap<String, Tensor>,
    /// Transpile options; the repair combinator may revise them.
    pub options: TranspileOptions,
    /// Generated DSL source (None in direct mode).
    pub dsl_source: Option<String>,
    /// Frontend-validated DSL program.
    pub dsl_program: Option<DslProgram>,
    /// Transcompiled (or directly generated) AscendC program.
    pub program: Option<AscProgram>,
    /// Concrete tiling values from host evaluation (pass 1).
    pub tiling: HashMap<String, i64>,
    /// Validator diagnostics from the most recent validation (the last
    /// transpile round, or the compile stage itself in direct mode).
    pub compile_diags: Vec<AscDiagnostic>,
    /// Set by the transpile stage: `compile_diags` already reflects a
    /// full validation of `program` (so the compile stage need not pay
    /// for a second one).
    pub transpiled: bool,
    /// Static-analyzer findings from the analyze stage (queue protocol,
    /// pipeline hazards, UB budget, GM bounds — the `ASCAN###` family).
    pub analysis_diags: Vec<AscDiagnostic>,
    /// Set by the analyze stage: `analysis_diags` reflects a full
    /// analysis of `program`.
    pub analyzed: bool,
    /// The backend-compiled kernel, once the compile stage ran. The
    /// program moves from [`Session::program`] into the kernel at that
    /// point (artifact dumps read it back via
    /// `PipelineArtifacts::program`).
    pub kernel: Option<CompiledKernel>,
    /// Backend execution output (tensors + optional cycles), once the
    /// simulate stage ran.
    pub exec: Option<ExecOutput>,
    /// Task reference outputs, computed just before simulation.
    pub reference: Option<HashMap<String, Tensor>>,
    /// Compile-feedback rounds consumed by the repair combinator.
    pub repair_rounds: usize,
    /// One report per executed stage, in execution order.
    pub reports: Vec<StageReport>,
    /// Every structured diagnostic the session saw (validator warnings
    /// included; the fatal one, if any, is also `TaskResult::failure`).
    pub diagnostics: Vec<Diagnostic>,
    /// Set by the compile stage: the program passed AscendC validation.
    pub compiled: bool,
    /// Set by the score stage: outputs matched the reference.
    pub correct: bool,
    started: Instant,
}

impl Session {
    pub fn new(task: &TaskSpec, cfg: &PipelineConfig) -> Session {
        Session {
            inputs: task.make_inputs(cfg.seed),
            options: cfg.options.clone(),
            dsl_source: None,
            dsl_program: None,
            program: None,
            tiling: HashMap::new(),
            compile_diags: Vec::new(),
            transpiled: false,
            analysis_diags: Vec::new(),
            analyzed: false,
            kernel: None,
            exec: None,
            reference: None,
            repair_rounds: 0,
            reports: Vec::new(),
            diagnostics: Vec::new(),
            compiled: false,
            correct: false,
            started: Instant::now(),
        }
    }

    /// Names of the executed stages, in order (mirrors
    /// `TaskResult::stage_timings`).
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.reports.iter().map(|r| r.name).collect()
    }

    /// The error-severity subset of [`Session::compile_diags`] — what the
    /// repair loop consumes ("did not compile" means this is non-empty).
    pub fn compile_errors(&self) -> Vec<AscDiagnostic> {
        self.compile_diags.iter().filter(|d| d.is_error()).cloned().collect()
    }

    /// The one `TaskResult` constructor: every path out of the pipeline —
    /// success or any-stage failure — funnels through here, so baselines
    /// (the configured backend's eager-cost hook with the *configured*
    /// core count), timings, and diagnostics can never diverge between
    /// paths.
    pub fn finish(
        mut self,
        task: &TaskSpec,
        cfg: &PipelineConfig,
        failure: Option<Diagnostic>,
    ) -> PipelineArtifacts {
        if let Some(d) = &failure {
            if !self.diagnostics.contains(d) {
                self.diagnostics.push(d.clone());
            }
        }
        let result = TaskResult {
            name: task.name.to_string(),
            category: task.category,
            backend: cfg.backend.name().to_string(),
            compiled: self.compiled,
            correct: self.correct && failure.is_none(),
            generated_cycles: self.exec.as_ref().and_then(|e| e.cycles),
            eager_cycles: cfg.backend.eager_cycles(task, cfg.cores),
            failure,
            repair_rounds: self.repair_rounds,
            analysis_errors: self.analysis_diags.iter().filter(|d| d.is_error()).count(),
            analysis_warnings: self.analysis_diags.iter().filter(|d| !d.is_error()).count(),
            pipeline_secs: self.started.elapsed().as_secs_f64(),
            stage_timings: self.reports.clone(),
            // the golden (L2) cross-check is a suite-level concern: the
            // worker in `coordinator::service::run_suite` fills this in
            // when `SuiteConfig::golden` is set
            golden: None,
            golden_seeds: Vec::new(),
        };
        PipelineArtifacts { result, session: self }
    }
}

/// One pipeline stage: reads its input artifacts off the [`Session`],
/// writes its outputs back, and fails with a structured [`Diagnostic`].
pub trait Stage {
    /// Canonical stage name (one of the `STAGE_*` constants).
    fn name(&self) -> &'static str;
    fn run(&self, task: &TaskSpec, cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic>;
}

/// The stage list the configuration selects. Ablations are stage-list
/// configurations, not inline branches: direct mode drops the DSL stages
/// entirely, `max_repair_rounds` parameterizes the repair combinator, and
/// generic-examples mode parameterizes the generator.
pub fn stage_list(cfg: &PipelineConfig) -> Vec<Box<dyn Stage>> {
    match cfg.mode {
        PipelineMode::Direct => vec![
            Box::new(GenerateStage),
            Box::new(CompileStage),
            Box::new(SimulateStage),
            Box::new(ScoreStage),
        ],
        PipelineMode::AscendCraft | PipelineMode::GenericExamples => vec![
            Box::new(GenerateStage),
            Box::new(FrontendStage),
            Box::new(RepairLoop { max_rounds: cfg.max_repair_rounds }),
            Box::new(AnalyzeStage),
            Box::new(CompileStage),
            Box::new(SimulateStage),
            Box::new(ScoreStage),
        ],
    }
}

/// DSL generation (paper §4.1) — or direct AscendC generation in the
/// ablation baseline. Writes `dsl_source` (+ scratch inputs) or `program`.
pub struct GenerateStage;

impl Stage for GenerateStage {
    fn name(&self) -> &'static str {
        STAGE_GENERATE
    }

    fn run(&self, task: &TaskSpec, cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        match cfg.mode {
            PipelineMode::Direct => {
                s.program = Some(DirectGenerator.generate(task));
                Ok(())
            }
            PipelineMode::AscendCraft | PipelineMode::GenericExamples => {
                let generator = synth::templates::KnowledgeBaseSynthesizer {
                    generic_only: cfg.mode == PipelineMode::GenericExamples,
                };
                let GenResult { dsl_source, scratch } =
                    generator.generate(task).map_err(Diagnostic::from)?;
                for (name, shape) in &scratch {
                    s.inputs.insert(name.clone(), Tensor::zeros(shape));
                }
                s.dsl_source = Some(dsl_source);
                Ok(())
            }
        }
    }
}

/// DSL frontend: parse + semantic validation (paper §3). Reads
/// `dsl_source`, writes `dsl_program`.
pub struct FrontendStage;

impl Stage for FrontendStage {
    fn name(&self) -> &'static str {
        STAGE_FRONTEND
    }

    fn run(&self, _task: &TaskSpec, _cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let source = s
            .dsl_source
            .as_deref()
            .ok_or_else(|| Diagnostic::internal(STAGE_FRONTEND, "no DSL source in session"))?;
        match dsl::frontend(source) {
            Ok(p) => {
                s.dsl_program = Some(p);
                Ok(())
            }
            Err(mut diags) => Err(Diagnostic::from(diags.remove(0))),
        }
    }
}

/// One transcompilation round: the four passes plus the final validation
/// ("compile"). Reads `dsl_program` + `inputs` + `options`; writes
/// `program`, `tiling`, and `compile_diags`. Standalone it performs no
/// repair — [`RepairLoop`] wraps it for the feedback flow.
pub struct TranspileStage;

impl Stage for TranspileStage {
    fn name(&self) -> &'static str {
        STAGE_TRANSPILE
    }

    fn run(&self, _task: &TaskSpec, _cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let out = {
            let dsl_program = s.dsl_program.as_ref().ok_or_else(|| {
                Diagnostic::internal(STAGE_TRANSPILE, "no validated DSL program in session")
            })?;
            transpile::transpile(dsl_program, &s.inputs, &s.options).map_err(Diagnostic::from)?
        };
        s.program = Some(out.program);
        s.tiling = out.tiling;
        s.compile_diags = out.diagnostics;
        s.transpiled = true;
        Ok(())
    }
}

/// Build the analysis environment a session implies: the concrete
/// tiling from host evaluation plus the element count of every host
/// tensor (inputs, zeroed outputs, generator scratch) a launch argument
/// can bind to.
fn analysis_env(s: &Session) -> crate::analysis::AnalyzeEnv {
    let numel = s.inputs.iter().map(|(n, t)| (n.clone(), t.numel())).collect();
    crate::analysis::AnalyzeEnv::new(s.tiling.clone()).with_numel(numel)
}

/// The per-pass correction-feedback combinator (paper §4.2): wraps
/// [`TranspileStage`], feeding validator errors *and* static-analyzer
/// errors to the repair engine and re-running until the program
/// compiles and analyzes cleanly or the round budget is spent.
/// `max_rounds = 0` is the feedback-ablated configuration.
pub struct RepairLoop {
    pub max_rounds: usize,
}

impl Stage for RepairLoop {
    fn name(&self) -> &'static str {
        STAGE_TRANSPILE
    }

    fn run(&self, task: &TaskSpec, cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        loop {
            TranspileStage.run(task, cfg, s)?;
            let mut errors = s.compile_errors();
            // analyzer findings join the feedback: path-sensitive errors
            // (queue protocol, UB budget, bounds) are repairable with the
            // same rules as their flat-validator cousins
            if let Some(program) = &s.program {
                errors.extend(crate::analysis::analyze_errors(program, &analysis_env(s)));
            }
            if errors.is_empty() {
                return Ok(());
            }
            if s.repair_rounds >= self.max_rounds {
                let mut d = Diagnostic::from(errors[0].clone());
                // the validator produced the code, but the *transpile*
                // stage is what failed — keep `failure.stage` consistent
                // with the stage_timings entry that records the failure
                d.stage = STAGE_TRANSPILE.to_string();
                d.message = format!("{} (after {} repair rounds)", d.message, s.repair_rounds);
                return Err(d);
            }
            let source = s.dsl_source.as_deref().unwrap_or_default();
            match repair::propose(&errors, source, &s.options) {
                Some(outcome) => {
                    s.repair_rounds += 1;
                    // record the errors this round repaired away, so the
                    // session's diagnostic list (--emit=diag) explains
                    // every repair round, not just the final verdict
                    for e in &errors {
                        let mut d = Diagnostic::from(e.clone());
                        d.stage = STAGE_TRANSPILE.to_string();
                        d.message =
                            format!("{} (repaired: round {})", d.message, s.repair_rounds);
                        s.diagnostics.push(d);
                    }
                    s.options = outcome.options;
                    match dsl::frontend(&outcome.dsl_source) {
                        Ok(p) => {
                            s.dsl_program = Some(p);
                            s.dsl_source = Some(outcome.dsl_source);
                        }
                        Err(mut diags) => {
                            s.dsl_source = Some(outcome.dsl_source);
                            let mut d = Diagnostic::from(diags.remove(0));
                            d.stage = STAGE_TRANSPILE.to_string();
                            d.message = format!("repaired DSL invalid: {}", d.message);
                            return Err(d);
                        }
                    }
                }
                None => {
                    let mut d = Diagnostic::from(errors[0].clone());
                    d.stage = STAGE_TRANSPILE.to_string();
                    d.message = format!("{} (no repair rule)", d.message);
                    return Err(d);
                }
            }
        }
    }
}

/// Ascend-semantics static analysis over the transpiled program: CFG +
/// dataflow passes for queue-protocol balance (ASCAN1xx), pipeline
/// hazards and use-before-init (ASCAN2xx/ASCAN401), UB budget under the
/// concrete tiling (ASCAN3xx), and GM bounds via corner evaluation
/// (ASCAN402). All findings land in [`Session::analysis_diags`] and the
/// session diagnostic list; the first error-severity finding fails the
/// stage. Warnings never fail anything — the analyzer's contract is
/// that errors describe a concrete violated execution.
pub struct AnalyzeStage;

impl Stage for AnalyzeStage {
    fn name(&self) -> &'static str {
        STAGE_ANALYZE
    }

    fn run(&self, _task: &TaskSpec, _cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let program = s
            .program
            .as_ref()
            .ok_or_else(|| Diagnostic::internal(STAGE_ANALYZE, "no AscendC program in session"))?;
        let diags = crate::analysis::analyze(program, &analysis_env(s));
        for d in &diags {
            let mut diag = Diagnostic::from(d.clone());
            diag.stage = STAGE_ANALYZE.to_string();
            s.diagnostics.push(diag);
        }
        s.analysis_diags = diags;
        s.analyzed = true;
        match s.analysis_diags.iter().find(|d| d.is_error()) {
            Some(first) => {
                let mut d = Diagnostic::from(first.clone());
                d.stage = STAGE_ANALYZE.to_string();
                Err(d)
            }
            None => Ok(()),
        }
    }
}

/// The "compile" gate, delegated to the configured backend: structural
/// validation of the session's program against the concrete tiling (the
/// paper's Comp@1 criterion). After a clean repair loop the backend
/// re-confirms zero errors for free (it reuses the transpile round's
/// validation); in direct mode it is the only compile check. Warnings are
/// recorded as non-fatal diagnostics. On success (and on failure — so
/// artifact dumps can still print the rejected program) the compiled
/// kernel lands in [`Session::kernel`].
pub struct CompileStage;

impl Stage for CompileStage {
    fn name(&self) -> &'static str {
        STAGE_COMPILE
    }

    fn run(&self, _task: &TaskSpec, cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let program = s
            .program
            .take()
            .ok_or_else(|| Diagnostic::internal(STAGE_COMPILE, "no AscendC program in session"))?;
        let report = cfg.backend.compile(s, program);
        s.diagnostics.extend(report.diagnostics);
        s.kernel = Some(report.kernel);
        match report.error {
            Some(d) => Err(d),
            None => {
                s.compiled = true;
                Ok(())
            }
        }
    }
}

/// Kernel execution on the configured backend (NPU simulation on the
/// default `ascend-sim`; functional-only on `cpu-ref`). Computes the task
/// reference first (it only reads inputs), then moves the input tensors
/// into the backend without an extra GM-sized clone (§Perf P5). Writes
/// `exec` + `reference`.
pub struct SimulateStage;

impl Stage for SimulateStage {
    fn name(&self) -> &'static str {
        STAGE_SIMULATE
    }

    fn run(&self, task: &TaskSpec, cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let kernel = s
            .kernel
            .take()
            .ok_or_else(|| Diagnostic::internal(STAGE_SIMULATE, "no compiled kernel in session"))?;
        s.reference = Some(task.reference(&s.inputs));
        let inputs = std::mem::take(&mut s.inputs);
        let outcome = cfg.backend.execute(&kernel, inputs, cfg.cores);
        s.kernel = Some(kernel);
        match outcome {
            Ok(o) => {
                s.exec = Some(o);
                Ok(())
            }
            Err(d) => Err(d),
        }
    }
}

/// Pass@1 scoring: every reference output must exist, match shape, and be
/// allclose within the task tolerances. Codes: `N101` missing output,
/// `N102` shape mismatch, `N103` numeric mismatch.
pub struct ScoreStage;

impl Stage for ScoreStage {
    fn name(&self) -> &'static str {
        STAGE_SCORE
    }

    fn run(&self, task: &TaskSpec, _cfg: &PipelineConfig, s: &mut Session) -> Result<(), Diagnostic> {
        let exec = s
            .exec
            .as_ref()
            .ok_or_else(|| Diagnostic::internal(STAGE_SCORE, "no backend output in session"))?;
        let reference = s
            .reference
            .as_ref()
            .ok_or_else(|| Diagnostic::internal(STAGE_SCORE, "no reference outputs in session"))?;
        for (name, want) in reference {
            let Some(got) = exec.tensors.get(name) else {
                return Err(Diagnostic::new(STAGE_SCORE, "N101", format!("output '{name}' missing")));
            };
            if got.shape != want.shape {
                return Err(Diagnostic::new(
                    STAGE_SCORE,
                    "N102",
                    format!("output '{name}' shape {:?} != reference {:?}", got.shape, want.shape),
                ));
            }
            let rep = allclose_report(got, want, task.rtol, task.atol);
            if !rep.ok {
                return Err(Diagnostic::new(
                    STAGE_SCORE,
                    "N103",
                    format!("output '{name}': {}", rep.summary()),
                ));
            }
        }
        s.correct = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::eager::eager_cycles_with_cores;
    use crate::bench_suite::tasks::task_by_name;

    #[test]
    fn diagnostic_display_includes_stage_code_and_line() {
        let d = Diagnostic::new(STAGE_FRONTEND, "D101", "tl.load outside copyin").with_line(7);
        let text = d.to_string();
        assert!(text.contains("frontend"), "{text}");
        assert!(text.contains("D101"), "{text}");
        assert!(text.contains("line 7"), "{text}");
    }

    #[test]
    fn diagnostic_json_round_trips() {
        let d = Diagnostic::new(STAGE_COMPILE, "A301", "UB over-subscribed").with_line(3);
        let parsed = Json::parse(&d.to_json().to_string()).unwrap();
        assert_eq!(Diagnostic::from_json(&parsed), Some(d));
        let no_line = Diagnostic::new(STAGE_SCORE, "N103", "drift");
        let parsed = Json::parse(&no_line.to_json().to_string()).unwrap();
        assert_eq!(Diagnostic::from_json(&parsed), Some(no_line));
    }

    #[test]
    fn conversions_keep_stage_and_code() {
        let d: Diagnostic = GenError::new("no template").into();
        assert_eq!((d.stage.as_str(), d.code.as_str()), (STAGE_GENERATE, "G001"));
        let d: Diagnostic = DslDiagnostic {
            code: "D201".into(),
            message: "m".into(),
            line: 4,
            severity: crate::diag::Severity::Error,
        }
        .into();
        assert_eq!((d.stage.as_str(), d.line), (STAGE_FRONTEND, Some(4)));
        let d: Diagnostic = TranspileError::new("pass1", "H201", "tiling".into()).into();
        assert_eq!((d.stage.as_str(), d.code.as_str()), (STAGE_TRANSPILE, "H201"));
        assert!(d.message.contains("pass1"));
        let d: Diagnostic = SimError::StepLimit.into();
        assert_eq!((d.stage.as_str(), d.code.as_str()), (STAGE_SIMULATE, "S104"));
    }

    #[test]
    fn stage_list_matches_mode() {
        let full = stage_list(&PipelineConfig::default());
        let names: Vec<_> = full.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            [
                STAGE_GENERATE,
                STAGE_FRONTEND,
                STAGE_TRANSPILE,
                STAGE_ANALYZE,
                STAGE_COMPILE,
                STAGE_SIMULATE,
                STAGE_SCORE
            ]
        );
        let direct = stage_list(&PipelineConfig {
            mode: PipelineMode::Direct,
            ..Default::default()
        });
        let names: Vec<_> = direct.iter().map(|s| s.name()).collect();
        assert_eq!(names, [STAGE_GENERATE, STAGE_COMPILE, STAGE_SIMULATE, STAGE_SCORE]);
    }

    #[test]
    fn stage_list_fingerprint_pins_version_and_order() {
        assert_eq!(
            stage_list_fingerprint(&PipelineConfig::default()),
            "v1:generate>frontend>transpile>analyze>compile>simulate>score"
        );
        let direct = PipelineConfig { mode: PipelineMode::Direct, ..Default::default() };
        assert_eq!(stage_list_fingerprint(&direct), "v1:generate>compile>simulate>score");
    }

    #[test]
    fn canonical_stage_names_round_trip() {
        for name in [
            STAGE_GENERATE,
            STAGE_FRONTEND,
            STAGE_TRANSPILE,
            STAGE_ANALYZE,
            STAGE_COMPILE,
            STAGE_SIMULATE,
            STAGE_SCORE,
        ] {
            assert_eq!(canonical_stage_name(name), Some(name));
        }
        assert_eq!(canonical_stage_name("linker"), None);
    }

    #[test]
    fn stage_report_json_round_trips() {
        let report =
            StageReport { name: STAGE_SIMULATE, wall_secs: 0.0625, outcome: StageOutcome::Failed };
        let parsed = Json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(StageReport::from_json(&parsed), Some(report));
        // non-canonical stage names are rejected, not interned
        let bogus = Json::parse(r#"{"name":"linker","secs":1.0,"outcome":"ok"}"#).unwrap();
        assert_eq!(StageReport::from_json(&bogus), None);
    }

    #[test]
    fn session_finish_is_the_single_result_constructor() {
        let task = task_by_name("relu").unwrap();
        let cfg = PipelineConfig { cores: 8, ..Default::default() };
        let session = Session::new(&task, &cfg);
        let failure = Diagnostic::new(STAGE_GENERATE, "G001", "boom");
        let art = session.finish(&task, &cfg, Some(failure.clone()));
        assert!(!art.result.compiled && !art.result.correct);
        assert_eq!(art.result.failure, Some(failure.clone()));
        // the fatal diagnostic is recorded on the session too
        assert!(art.session.diagnostics.contains(&failure));
        // the configured core count drives the eager baseline (not the
        // hard-coded default) — the satellite regression this API fixes
        assert_eq!(
            art.result.eager_cycles,
            eager_cycles_with_cores(&task, 8)
        );
        // every result names the backend that produced it
        assert_eq!(art.result.backend, crate::backend::BACKEND_ASCEND_SIM);
    }
}
