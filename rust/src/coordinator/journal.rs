//! Content-addressed suite result journal: incremental, resumable runs.
//!
//! `suite --journal PATH` records every finished task as one JSON line
//! keyed by a hash of the full execution tuple — task spec, seed, mode,
//! cores, backend, repair budget, transpile options, stage-list version,
//! golden-seed count (see [`KEY_FIELDS`], pinned to
//! `docs/ARCHITECTURE.md` by `tests/docs_spec.rs`). A re-run with the
//! same journal skips every tuple that already has a durable record, so
//! only *changed* configurations (or new tasks) execute; `--resume PATH`
//! additionally tolerates a partial trailing record — the signature of a
//! run killed mid-append — by truncating the file to its durable prefix
//! and re-running exactly the records that never landed.
//!
//! Durability model: records are appended one line at a time, flushed and
//! fsync'd per record (a suite task costs orders of magnitude more than
//! an fsync). A record is durable iff its terminating newline is on
//! disk; [`crate::util::json::parse_jsonl`] draws exactly that line.
//! Append-only writes can only ever corrupt the *tail*, so tolerant mode
//! still refuses malformed interior lines — that file was not produced
//! by this writer, and silently skipping records would fake coverage.
//!
//! File format (`format`/`version` pinned below):
//!
//! ```text
//! {"format":"ascendcraft-suite-journal","version":1}
//! {"key":"64af…16 hex…","result":{…TaskResult::to_json…},"task":"relu"}
//! …one line per completed (backend, task) tuple…
//! ```

use crate::bench_suite::metrics::TaskResult;
use crate::bench_suite::spec::TaskSpec;
use crate::coordinator::pipeline::PipelineConfig;
use crate::coordinator::stage::stage_list_fingerprint;
use crate::util::json::{parse_jsonl, Json};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Journal header `format` value — a wrong value means the file is some
/// other JSON-lines document and is rejected rather than appended to.
pub const JOURNAL_FORMAT: &str = "ascendcraft-suite-journal";

/// Journal schema version; bump on incompatible record changes.
pub const JOURNAL_VERSION: u64 = 1;

/// The fields of the canonical key string, in order. Every field that
/// changes execution semantics must appear here: a tuple's recorded
/// result is replayed *instead of running the pipeline*, so any
/// semantic input missing from the key would let a stale result
/// masquerade as current. Pinned to the table in `docs/ARCHITECTURE.md`
/// ("Suite at scale") by `tests/docs_spec.rs`.
pub const KEY_FIELDS: [&str; 9] =
    ["spec", "seed", "mode", "cores", "backend", "repair", "options", "stages", "golden"];

/// FNV-1a 64-bit over raw bytes — the same constants as the task-spec
/// hash in `bench_suite/spec.rs`, hand-rolled per the zero-crates policy.
/// Pinned against golden values in `tests/journal_props.rs` so an
/// accidental constant change fails loudly (every journal key would
/// silently miss otherwise).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The readable canonical string a journal key hashes:
/// `spec=<TaskSpec Debug>;seed=…;mode=…;cores=…;backend=…;repair=…;`
/// `options=<TranspileOptions Debug>;stages=<stage-list fingerprint>;`
/// `golden=<effective golden seed count, 0 when the check is off>`.
/// `TaskSpec` and `TranspileOptions` are plain data (no function
/// pointers, no addresses), so their `Debug` output is a deterministic
/// fingerprint of everything the pipeline reads from them.
pub fn canonical_key(task: &TaskSpec, cfg: &PipelineConfig, golden_seeds: usize) -> String {
    let values: [String; 9] = [
        format!("{task:?}"),
        cfg.seed.to_string(),
        format!("{:?}", cfg.mode),
        cfg.cores.to_string(),
        cfg.backend.name().to_string(),
        cfg.max_repair_rounds.to_string(),
        format!("{:?}", cfg.options),
        stage_list_fingerprint(cfg),
        golden_seeds.to_string(),
    ];
    KEY_FIELDS
        .iter()
        .zip(values.iter())
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(";")
}

/// Hash a canonical string into the 16-hex-digit journal key. Split out
/// from [`task_key`] so tests can pin literal key values on fixed
/// canonical strings.
pub fn key_of_canonical(canonical: &str) -> String {
    format!("{:016x}", fnv1a64(canonical.as_bytes()))
}

/// The content-address of one (task, pipeline, golden) execution tuple.
pub fn task_key(task: &TaskSpec, cfg: &PipelineConfig, golden_seeds: usize) -> String {
    key_of_canonical(&canonical_key(task, cfg, golden_seeds))
}

/// An open suite journal: the in-memory record map plus the append
/// handle. Construction validates (and in tolerant mode, repairs) the
/// on-disk file; see [`Journal::open`].
pub struct Journal {
    path: PathBuf,
    file: File,
    records: BTreeMap<String, TaskResult>,
    /// Tolerant open dropped a partial trailing record (the kill marker).
    pub dropped_partial: bool,
    hits: usize,
    appended: usize,
}

impl Journal {
    /// Open (or create) a journal. `tolerant` is the `--resume`
    /// semantics: a truncated final line — a record whose append was
    /// interrupted — is dropped and the file is truncated back to its
    /// durable prefix. Strict mode (`--journal`) errors on *any*
    /// malformed content instead, as does either mode on interior
    /// corruption or a foreign header.
    pub fn open(path: &Path, tolerant: bool) -> Result<Journal, String> {
        let existing = match std::fs::read_to_string(path) {
            // an empty file (e.g. a run killed between create and the
            // header write) is a fresh journal, not a malformed one
            Ok(text) if text.is_empty() => None,
            Ok(text) => Some(text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(format!("read {}: {e}", path.display())),
        };
        let mut records = BTreeMap::new();
        let mut dropped_partial = false;
        match existing {
            None => {
                let mut header = Json::obj();
                header.set("format", JOURNAL_FORMAT).set("version", JOURNAL_VERSION);
                std::fs::write(path, format!("{}\n", header.to_string()))
                    .map_err(|e| format!("create {}: {e}", path.display()))?;
            }
            Some(text) => {
                let doc = parse_jsonl(&text, tolerant)
                    .map_err(|e| format!("{}: {e}", path.display()))?;
                dropped_partial = doc.dropped_partial;
                let mut lines = doc.lines.into_iter();
                let header = lines.next().ok_or_else(|| {
                    format!("{}: missing journal header", path.display())
                })?;
                let format = header.0.get("format").and_then(Json::as_str);
                let version = header.0.get("version").and_then(Json::as_f64);
                if format != Some(JOURNAL_FORMAT) || version != Some(JOURNAL_VERSION as f64) {
                    return Err(format!(
                        "{}: not a {JOURNAL_FORMAT} v{JOURNAL_VERSION} file",
                        path.display()
                    ));
                }
                let mut durable_len = doc.durable_len;
                let total = lines.len();
                for (i, (line, end)) in lines.enumerate() {
                    match Self::record_of(&line) {
                        Some((key, result)) => {
                            records.insert(key, result);
                        }
                        None if tolerant && i + 1 == total => {
                            // a structurally-valid JSON line that is not a
                            // valid record can only be a torn tail that
                            // happened to parse — drop it like any partial
                            durable_len = end - line_len(&text, end);
                            dropped_partial = true;
                        }
                        None => {
                            return Err(format!(
                                "{}: malformed journal record on line {}",
                                path.display(),
                                i + 2
                            ));
                        }
                    }
                }
                if dropped_partial && durable_len < text.len() {
                    let f = OpenOptions::new()
                        .write(true)
                        .open(path)
                        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
                    f.set_len(durable_len as u64)
                        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
                }
            }
        }
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| format!("append-open {}: {e}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            file,
            records,
            dropped_partial,
            hits: 0,
            appended: 0,
        })
    }

    fn record_of(line: &Json) -> Option<(String, TaskResult)> {
        let key = line.get("key")?.as_str()?.to_string();
        let result = TaskResult::from_json(line.get("result")?)?;
        Some((key, result))
    }

    /// The recorded result for a key, if any. Callers that replay a hit
    /// should call [`Journal::note_hit`] so the run summary can report
    /// cached-vs-executed counts.
    pub fn lookup(&self, key: &str) -> Option<&TaskResult> {
        self.records.get(key)
    }

    pub fn note_hit(&mut self) {
        self.hits += 1;
    }

    /// Append one finished tuple as a durable record: a single line,
    /// flushed and fsync'd before returning.
    pub fn append(&mut self, key: &str, result: &TaskResult) -> Result<(), String> {
        let mut line = Json::obj();
        line.set("key", key).set("task", result.name.as_str()).set("result", result.to_json());
        let text = format!("{}\n", line.to_string());
        self.file
            .write_all(text.as_bytes())
            .and_then(|()| self.file.flush())
            .and_then(|()| self.file.sync_data())
            .map_err(|e| format!("append {}: {e}", self.path.display()))?;
        self.records.insert(key.to_string(), result.clone());
        self.appended += 1;
        Ok(())
    }

    /// Number of durable records currently known.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// (cache hits replayed, records appended) since open.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits, self.appended)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Byte length of the line ending at byte offset `end` (including its
/// `'\n'`), used to walk one durable line backwards when the final
/// record — not the final line — is the torn one. Shared with the
/// autotuner's best-config store (`tune/store.rs`), which replays the
/// same torn-tail repair over its own record schema.
pub(crate) fn line_len(text: &str, end: usize) -> usize {
    let body = &text.as_bytes()[..end.saturating_sub(1)];
    let start = body.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
    end - start
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendRegistry;
    use crate::bench_suite::tasks::task_by_name;
    use crate::coordinator::pipeline::PipelineMode;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "ascendcraft_journal_unit_{tag}_{}.jsonl",
            std::process::id()
        ))
    }

    fn sample_result(name: &str) -> TaskResult {
        TaskResult {
            name: name.to_string(),
            category: crate::bench_suite::spec::Category::Math,
            backend: "ascend-sim".into(),
            compiled: true,
            correct: true,
            generated_cycles: Some(250.0),
            eager_cycles: 1000.0,
            failure: None,
            repair_rounds: 1,
            analysis_errors: 0,
            analysis_warnings: 0,
            pipeline_secs: 0.5,
            stage_timings: Vec::new(),
            golden: None,
            golden_seeds: Vec::new(),
        }
    }

    #[test]
    fn fresh_journal_writes_header_and_round_trips_records() {
        let path = temp_path("fresh");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path, false).unwrap();
            assert!(j.is_empty() && !j.dropped_partial);
            j.append("00000000000000aa", &sample_result("cumsum")).unwrap();
            j.append("00000000000000bb", &sample_result("relu")).unwrap();
            assert_eq!(j.stats(), (0, 2));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(&format!("{{\"format\":\"{JOURNAL_FORMAT}\"")), "{text}");
        assert_eq!(text.lines().count(), 3);
        let j = Journal::open(&path, false).unwrap();
        assert_eq!(j.len(), 2);
        assert_eq!(j.lookup("00000000000000aa"), Some(&sample_result("cumsum")));
        assert_eq!(j.lookup("missing"), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tolerant_open_truncates_a_torn_tail_strict_rejects_it() {
        let path = temp_path("torn");
        let _ = std::fs::remove_file(&path);
        {
            let mut j = Journal::open(&path, false).unwrap();
            j.append("00000000000000aa", &sample_result("cumsum")).unwrap();
            j.append("00000000000000bb", &sample_result("relu")).unwrap();
        }
        let full = std::fs::read_to_string(&path).unwrap();
        // kill mid-append: half of the final record, no newline
        let cut = full.len() - 20;
        std::fs::write(&path, &full[..cut]).unwrap();
        assert!(Journal::open(&path, false).is_err(), "strict must reject a torn tail");
        let durable: String =
            full.lines().take(2).map(|l| format!("{l}\n")).collect();
        let j = Journal::open(&path, true).unwrap();
        assert!(j.dropped_partial);
        assert_eq!(j.len(), 1);
        assert_eq!(j.lookup("00000000000000bb"), None);
        // the file was truncated back to its durable prefix, byte-exact
        assert_eq!(std::fs::read_to_string(&path).unwrap(), durable);
        // ... and the repaired file now opens strict
        assert!(Journal::open(&path, false).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn foreign_files_are_rejected_in_both_modes() {
        let path = temp_path("foreign");
        std::fs::write(&path, "{\"format\":\"something-else\",\"version\":1}\n").unwrap();
        assert!(Journal::open(&path, false).is_err());
        assert!(Journal::open(&path, true).is_err());
        std::fs::write(&path, "not json at all\n").unwrap();
        assert!(Journal::open(&path, false).is_err());
        assert!(Journal::open(&path, true).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn key_changes_with_every_tuple_field() {
        let task = task_by_name("relu").unwrap();
        let cfg = PipelineConfig::default();
        let base = task_key(&task, &cfg, 0);
        assert_eq!(base, task_key(&task, &cfg, 0), "key must be deterministic");
        assert_eq!(base.len(), 16);
        let other_task = task_by_name("gelu").unwrap();
        assert_ne!(base, task_key(&other_task, &cfg, 0));
        assert_ne!(base, task_key(&task, &PipelineConfig { seed: 7, ..cfg.clone() }, 0));
        assert_ne!(base, task_key(&task, &PipelineConfig { cores: 4, ..cfg.clone() }, 0));
        assert_ne!(
            base,
            task_key(&task, &PipelineConfig { max_repair_rounds: 0, ..cfg.clone() }, 0)
        );
        assert_ne!(
            base,
            task_key(&task, &PipelineConfig { mode: PipelineMode::Direct, ..cfg.clone() }, 0)
        );
        let cpu = BackendRegistry::builtin().get("cpu-ref").unwrap();
        assert_ne!(base, task_key(&task, &PipelineConfig { backend: cpu, ..cfg.clone() }, 0));
        let mut opts = cfg.clone();
        opts.options.queue_depth = 4;
        assert_ne!(base, task_key(&task, &opts, 0));
        let mut tuned = cfg.clone();
        tuned.options.tiling_overrides = vec![("tile_len".to_string(), 1024)];
        assert_ne!(base, task_key(&task, &tuned, 0), "tiling overrides are part of the tuple");
        assert_ne!(base, task_key(&task, &cfg, 1), "golden seeds are part of the tuple");
    }

    #[test]
    fn canonical_key_names_every_pinned_field() {
        let task = task_by_name("relu").unwrap();
        let canonical = canonical_key(&task, &PipelineConfig::default(), 2);
        for field in KEY_FIELDS {
            assert!(canonical.contains(&format!("{field}=")), "{field} missing: {canonical}");
        }
        assert!(canonical.contains("backend=ascend-sim"), "{canonical}");
        assert!(canonical.contains("golden=2"), "{canonical}");
        assert!(canonical.contains("stages=v1:generate>"), "{canonical}");
    }
}
