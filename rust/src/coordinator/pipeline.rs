//! End-to-end per-task pipeline: the paper's Figure 3 flow.
//!
//! ```text
//! task ──► DSL generation (synth) ──► DSL frontend (parse+validate)
//!      ──► transcompile passes 1–4 ──► "compile" (AscendC validator)
//!            ▲                │ errors
//!            └── repair ◄─────┘            (bounded feedback rounds)
//!      ──► NPU simulation (functional+timing) ──► Pass@1 / Fastₓ scoring
//! ```

use crate::ascendc::AscProgram;
use crate::baselines::eager::eager_cycles;
use crate::bench_suite::metrics::TaskResult;
use crate::bench_suite::spec::TaskSpec;
use crate::dsl;
use crate::sim;
use crate::synth::{self, direct::DirectGenerator, repair, GenResult, Generator};
use crate::transpile::{self, TranspileOptions};
use crate::util::compare::allclose_report;
use crate::util::tensor::Tensor;
use std::time::Instant;

/// Which generation path to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    /// Full AscendCraft: DSL generation + 4-pass transcompilation + repair.
    AscendCraft,
    /// Direct AscendC generation baseline (E3).
    Direct,
    /// Category knowledge ablated: generic elementwise template only.
    GenericExamples,
}

/// Pipeline configuration (ablation knobs included).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub mode: PipelineMode,
    pub options: TranspileOptions,
    /// Max compile-feedback rounds (0 = feedback ablated off).
    pub max_repair_rounds: usize,
    /// Input-data seed.
    pub seed: u64,
    /// Simulated core count.
    pub cores: usize,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            mode: PipelineMode::AscendCraft,
            options: TranspileOptions::default(),
            max_repair_rounds: 4,
            seed: 0xA5CE_17D0,
            cores: crate::sim::cost::NUM_CORES,
        }
    }
}

/// Everything the pipeline produced for one task (result + artifacts).
#[derive(Clone, Debug)]
pub struct PipelineArtifacts {
    pub result: TaskResult,
    pub dsl_source: Option<String>,
    pub program: Option<AscProgram>,
}

/// Run one task through the configured pipeline.
pub fn run_task(task: &TaskSpec, cfg: &PipelineConfig) -> PipelineArtifacts {
    let started = Instant::now();
    let fail = |compiled: bool, msg: String, dsl: Option<String>, rounds: usize| PipelineArtifacts {
        result: TaskResult {
            name: task.name.to_string(),
            category: task.category,
            compiled,
            correct: false,
            generated_cycles: None,
            eager_cycles: eager_cycles(task),
            failure: Some(msg),
            repair_rounds: rounds,
            pipeline_secs: started.elapsed().as_secs_f64(),
            golden: None,
            golden_seeds: Vec::new(),
        },
        dsl_source: dsl,
        program: None,
    };

    let mut inputs = task.make_inputs(cfg.seed);

    // --- generation stage ---
    let (program, dsl_source, rounds) = match cfg.mode {
        PipelineMode::Direct => {
            let program = DirectGenerator.generate(task);
            let env = crate::ascendc::validate::ValidateEnv::new(Default::default());
            let errors = crate::ascendc::validate::validate_errors(&program, &env);
            if !errors.is_empty() {
                return fail(
                    false,
                    format!("direct generation failed to compile: {}", errors[0].message),
                    None,
                    0,
                );
            }
            (program, None, 0)
        }
        PipelineMode::AscendCraft | PipelineMode::GenericExamples => {
            let generator = synth::templates::KnowledgeBaseSynthesizer {
                generic_only: cfg.mode == PipelineMode::GenericExamples,
            };
            let GenResult { mut dsl_source, scratch } = match generator.generate(task) {
                Ok(r) => r,
                Err(e) => return fail(false, format!("generation: {e}"), None, 0),
            };
            for (name, shape) in &scratch {
                inputs.insert(name.clone(), Tensor::zeros(shape));
            }
            // DSL frontend
            let mut dsl_program = match dsl::frontend(&dsl_source) {
                Ok(p) => p,
                Err(diags) => {
                    return fail(
                        false,
                        format!("DSL validation: {}", diags[0].message),
                        Some(dsl_source),
                        0,
                    )
                }
            };
            // transcompile with per-pass correction feedback
            let mut options = cfg.options.clone();
            let mut rounds = 0usize;
            let program = loop {
                let out = match transpile::transpile(&dsl_program, &inputs, &options) {
                    Ok(o) => o,
                    Err(e) => return fail(false, format!("transpile: {e}"), Some(dsl_source), rounds),
                };
                let errors: Vec<_> =
                    out.diagnostics.iter().filter(|d| d.is_error()).cloned().collect();
                if errors.is_empty() {
                    break out.program;
                }
                if rounds >= cfg.max_repair_rounds {
                    return fail(
                        false,
                        format!("compile: {} (after {rounds} repair rounds)", errors[0].message),
                        Some(dsl_source),
                        rounds,
                    );
                }
                match repair::propose(&errors, &dsl_source, &options) {
                    Some(outcome) => {
                        rounds += 1;
                        dsl_source = outcome.dsl_source;
                        options = outcome.options;
                        dsl_program = match dsl::frontend(&dsl_source) {
                            Ok(p) => p,
                            Err(diags) => {
                                return fail(
                                    false,
                                    format!("repaired DSL invalid: {}", diags[0].message),
                                    Some(dsl_source),
                                    rounds,
                                )
                            }
                        };
                    }
                    None => {
                        return fail(
                            false,
                            format!("compile: {} (no repair rule)", errors[0].message),
                            Some(dsl_source),
                            rounds,
                        )
                    }
                }
            };
            (program, Some(dsl_source), rounds)
        }
    };

    // --- execution + scoring ---
    // reference first (it only reads inputs), then move the tensors into
    // the simulator without an extra GM-sized clone (§Perf P5)
    let reference = task.reference(&inputs);
    let sim_out = match sim::simulate_owned(&program, inputs, cfg.cores) {
        Ok(o) => o,
        Err(e) => {
            let mut art = fail(true, format!("simulation: {e}"), dsl_source.clone(), rounds);
            art.program = Some(program);
            return art;
        }
    };
    let mut correct = true;
    let mut failure = None;
    for (name, want) in &reference {
        let Some(got) = sim_out.tensors.get(name) else {
            correct = false;
            failure = Some(format!("output '{name}' missing"));
            break;
        };
        if got.shape != want.shape {
            correct = false;
            failure = Some(format!(
                "output '{name}' shape {:?} != reference {:?}",
                got.shape, want.shape
            ));
            break;
        }
        let rep = allclose_report(got, want, task.rtol, task.atol);
        if !rep.ok {
            correct = false;
            failure = Some(format!("output '{name}': {}", rep.summary()));
            break;
        }
    }

    PipelineArtifacts {
        result: TaskResult {
            name: task.name.to_string(),
            category: task.category,
            compiled: true,
            correct,
            generated_cycles: Some(sim_out.timing.total_cycles),
            eager_cycles: eager_cycles(task),
            failure,
            repair_rounds: rounds,
            pipeline_secs: started.elapsed().as_secs_f64(),
            // the golden (L2) cross-check is a suite-level concern: the
            // worker in `coordinator::service::run_suite` fills this in
            // when `SuiteConfig::golden` is set
            golden: None,
            golden_seeds: Vec::new(),
        },
        dsl_source,
        program: Some(program),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::tasks::task_by_name;

    fn run(name: &str) -> PipelineArtifacts {
        run_task(&task_by_name(name).unwrap(), &PipelineConfig::default())
    }

    #[test]
    fn relu_end_to_end() {
        let art = run("relu");
        assert!(art.result.compiled, "{:?}", art.result.failure);
        assert!(art.result.correct, "{:?}", art.result.failure);
        assert!(art.result.generated_cycles.unwrap() > 0.0);
    }

    #[test]
    fn softmax_end_to_end() {
        let art = run("softmax");
        assert!(art.result.correct, "{:?}", art.result.failure);
    }

    #[test]
    fn mse_loss_multi_kernel_end_to_end() {
        let art = run("mse_loss");
        assert!(art.result.correct, "{:?}", art.result.failure);
        // two kernels: partial + combine
        assert_eq!(art.program.unwrap().kernels.len(), 2);
    }

    #[test]
    fn adam_repairs_ub_oversubscription() {
        let art = run("adam");
        assert!(art.result.correct, "{:?}", art.result.failure);
        assert!(art.result.repair_rounds >= 1, "adam should trip the UB budget");
    }

    #[test]
    fn mask_cumsum_fails_to_compile() {
        let art = run("mask_cumsum");
        assert!(!art.result.compiled);
        let msg = art.result.failure.unwrap();
        assert!(msg.contains("bool") || msg.contains("A40"), "{msg}");
    }

    #[test]
    fn cross_entropy_fails_numerically() {
        let art = run("cross_entropy");
        assert!(art.result.compiled, "{:?}", art.result.failure);
        assert!(!art.result.correct, "fused log-softmax without rescale must overflow");
    }

    #[test]
    fn direct_mode_fails_on_complex_tasks() {
        let cfg = PipelineConfig { mode: PipelineMode::Direct, ..Default::default() };
        let art = run_task(&task_by_name("softmax").unwrap(), &cfg);
        assert!(!art.result.compiled);
        let art = run_task(&task_by_name("relu").unwrap(), &cfg);
        assert!(art.result.compiled);
        assert!(art.result.correct, "{:?}", art.result.failure);
    }
}
