//! End-to-end per-task pipeline: the paper's Figure 3 flow, as a thin
//! driver over the staged compilation-session API in [`super::stage`].
//!
//! ```text
//! task ──► DSL generation (synth) ──► DSL frontend (parse+validate)
//!      ──► transcompile passes 1–4 ──► "compile" (AscendC validator)
//!            ▲                │ errors
//!            └── repair ◄─────┘            (bounded feedback rounds)
//!      ──► NPU simulation (functional+timing) ──► Pass@1 / Fastₓ scoring
//! ```
//!
//! [`run_task`] builds the stage list the [`PipelineConfig`] selects
//! (ablations are stage-list configurations, not inline branches), walks
//! it on a [`Session`], and returns the full session alongside the
//! [`TaskResult`] — per-stage wall times in `TaskResult::stage_timings`,
//! failures as structured [`super::stage::Diagnostic`]s.

use super::stage::{stage_list, Session, Stage, StageOutcome, StageReport};
use crate::ascendc::AscProgram;
use crate::backend::{default_backend, Backend};
use crate::bench_suite::metrics::TaskResult;
use crate::bench_suite::spec::TaskSpec;
use crate::transpile::TranspileOptions;
use std::fmt;
use std::sync::Arc;
use std::time::Instant;

/// Which generation path to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineMode {
    /// Full AscendCraft: DSL generation + 4-pass transcompilation + repair.
    AscendCraft,
    /// Direct AscendC generation baseline (E3).
    Direct,
    /// Category knowledge ablated: generic elementwise template only.
    GenericExamples,
}

/// Pipeline configuration (ablation knobs included).
#[derive(Clone)]
pub struct PipelineConfig {
    pub mode: PipelineMode,
    pub options: TranspileOptions,
    /// Max compile-feedback rounds (0 = feedback ablated off).
    pub max_repair_rounds: usize,
    /// Input-data seed.
    pub seed: u64,
    /// Simulated core count (drives both the generated kernel's timing and
    /// the eager baseline, so Fastₓ compares like with like).
    pub cores: usize,
    /// Execution backend the compile/simulate stages target (default:
    /// the NPU simulator, `crate::backend::AscendSimBackend`). Shared —
    /// suite workers clone the config, not the backend.
    pub backend: Arc<dyn Backend>,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            mode: PipelineMode::AscendCraft,
            options: TranspileOptions::default(),
            max_repair_rounds: 4,
            seed: 0xA5CE_17D0,
            cores: crate::sim::cost::NUM_CORES,
            backend: default_backend(),
        }
    }
}

impl fmt::Debug for PipelineConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // manual impl: `dyn Backend` is not Debug; its name is what matters
        f.debug_struct("PipelineConfig")
            .field("mode", &self.mode)
            .field("options", &self.options)
            .field("max_repair_rounds", &self.max_repair_rounds)
            .field("seed", &self.seed)
            .field("cores", &self.cores)
            .field("backend", &self.backend.name())
            .finish()
    }
}

/// Everything the pipeline produced for one task: the scored
/// [`TaskResult`] plus the full [`Session`] with every intermediate
/// artifact (`ascendcraft compile --emit=…` dumps these).
#[derive(Clone, Debug)]
pub struct PipelineArtifacts {
    pub result: TaskResult,
    pub session: Session,
}

impl PipelineArtifacts {
    /// Generated DSL source, if the configured pipeline produced one.
    pub fn dsl_source(&self) -> Option<&str> {
        self.session.dsl_source.as_deref()
    }

    /// Final AscendC program, if one was produced. After the compile
    /// stage the program lives inside the backend-compiled kernel; before
    /// it (or when compile never ran) it is still on the session.
    pub fn program(&self) -> Option<&AscProgram> {
        self.session.kernel.as_ref().map(|k| &k.program).or(self.session.program.as_ref())
    }
}

/// Run one task through the stage list the configuration selects.
pub fn run_task(task: &TaskSpec, cfg: &PipelineConfig) -> PipelineArtifacts {
    run_stages(task, cfg, &stage_list(cfg))
}

/// The driver proper: walk an explicit stage list, timing each stage into
/// a [`StageReport`], and stop at the first structured failure. Exposed so
/// tests and tools can run hand-assembled stage lists.
pub fn run_stages(
    task: &TaskSpec,
    cfg: &PipelineConfig,
    stages: &[Box<dyn Stage>],
) -> PipelineArtifacts {
    let mut session = Session::new(task, cfg);
    for stage in stages {
        let started = Instant::now();
        let outcome = stage.run(task, cfg, &mut session);
        session.reports.push(StageReport {
            name: stage.name(),
            wall_secs: started.elapsed().as_secs_f64(),
            outcome: if outcome.is_ok() { StageOutcome::Ok } else { StageOutcome::Failed },
        });
        if let Err(diagnostic) = outcome {
            return session.finish(task, cfg, Some(diagnostic));
        }
    }
    session.finish(task, cfg, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::tasks::task_by_name;

    fn run(name: &str) -> PipelineArtifacts {
        run_task(&task_by_name(name).unwrap(), &PipelineConfig::default())
    }

    #[test]
    fn relu_end_to_end() {
        let art = run("relu");
        assert!(art.result.compiled, "{:?}", art.result.failure);
        assert!(art.result.correct, "{:?}", art.result.failure);
        assert!(art.result.generated_cycles.unwrap() > 0.0);
    }

    #[test]
    fn softmax_end_to_end() {
        let art = run("softmax");
        assert!(art.result.correct, "{:?}", art.result.failure);
    }

    #[test]
    fn mse_loss_multi_kernel_end_to_end() {
        let art = run("mse_loss");
        assert!(art.result.correct, "{:?}", art.result.failure);
        // two kernels: partial + combine
        assert_eq!(art.program().unwrap().kernels.len(), 2);
    }

    #[test]
    fn adam_repairs_ub_oversubscription() {
        let art = run("adam");
        assert!(art.result.correct, "{:?}", art.result.failure);
        assert!(art.result.repair_rounds >= 1, "adam should trip the UB budget");
    }

    #[test]
    fn mask_cumsum_fails_to_compile() {
        let art = run("mask_cumsum");
        assert!(!art.result.compiled);
        let d = art.result.failure.unwrap();
        assert!(d.message.contains("bool") || d.code.starts_with("A40"), "{d}");
        assert!(!d.stage.is_empty() && !d.code.is_empty(), "{d}");
    }

    #[test]
    fn cross_entropy_fails_numerically() {
        let art = run("cross_entropy");
        assert!(art.result.compiled, "{:?}", art.result.failure);
        assert!(!art.result.correct, "fused log-softmax without rescale must overflow");
    }

    #[test]
    fn direct_mode_fails_on_complex_tasks() {
        let cfg = PipelineConfig { mode: PipelineMode::Direct, ..Default::default() };
        let art = run_task(&task_by_name("softmax").unwrap(), &cfg);
        assert!(!art.result.compiled);
        let art = run_task(&task_by_name("relu").unwrap(), &cfg);
        assert!(art.result.compiled);
        assert!(art.result.correct, "{:?}", art.result.failure);
    }

    #[test]
    fn every_stage_is_timed_in_order() {
        let art = run("relu");
        let names: Vec<_> = art.result.stage_timings.iter().map(|r| r.name).collect();
        assert_eq!(
            names,
            ["generate", "frontend", "transpile", "analyze", "compile", "simulate", "score"]
        );
        assert!(art.result.stage_timings.iter().all(|r| r.wall_secs >= 0.0));
        assert!(art.result.stage_timings.iter().all(|r| r.outcome == StageOutcome::Ok));
    }

    #[test]
    fn failed_stage_terminates_the_report_list() {
        let art = run("mask_cumsum");
        let last = art.result.stage_timings.last().unwrap();
        assert_eq!(last.name, "transpile");
        assert_eq!(last.outcome, StageOutcome::Failed);
        // nothing after the failing stage ran
        assert_eq!(art.result.stage_timings.len(), 3);
        assert!(art.session.exec.is_none());
    }
}
