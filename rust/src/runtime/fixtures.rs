//! Rust references (L3) for oracle fixtures that are not benchmark-suite
//! tasks, plus their cross-check entry point.
//!
//! The op-set-coverage fixtures (`avgpool2d_pad`, `argmax_rows`,
//! `window_sum`) exist to exercise interpreter features end-to-end —
//! divide-by-count padded pooling, `iota` + integer dtypes, and
//! `while` + `dynamic-slice` — rather than to benchmark kernels, so they
//! live outside the 52-task MultiKernelBench population
//! (`bench_suite::tasks`). This module holds their hand-rolled reference
//! numerics and the cross-check used by `ascendcraft oracle` and
//! `rust/tests/golden_oracle.rs`, mirroring how the mHC artifacts get
//! dedicated references in [`crate::mhc`].

use super::OracleRegistry;
use crate::util::compare::allclose_report;
use crate::util::rng::XorShiftRng;
use crate::util::tensor::{DType, Tensor};

/// Fixture names covered by [`cross_check_fixture`], i.e. every artifact
/// that has a reference here instead of a benchmark task.
pub const EXTRA_FIXTURES: &[&str] = &["avgpool2d_pad", "argmax_rows", "window_sum"];

/// Average pooling over `[batch, h, w]` with window 3, stride 2,
/// symmetric padding 1, dividing by the number of in-bounds elements
/// (padding excluded from the count) — the reference for the
/// `avgpool2d_pad` fixture's divide-by-count lowering.
pub fn avgpool2d_pad_ref(x: &Tensor) -> Tensor {
    const WIN: usize = 3;
    const STRIDE: usize = 2;
    const PAD: i64 = 1;
    let (b, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let out_h = (h + 2 * PAD as usize - WIN) / STRIDE + 1;
    let out_w = (w + 2 * PAD as usize - WIN) / STRIDE + 1;
    let mut data = Vec::with_capacity(b * out_h * out_w);
    for bi in 0..b {
        for oh in 0..out_h {
            for ow in 0..out_w {
                let mut acc = 0.0f32;
                let mut count = 0usize;
                for ky in 0..WIN {
                    for kx in 0..WIN {
                        let iy = (oh * STRIDE + ky) as i64 - PAD;
                        let ix = (ow * STRIDE + kx) as i64 - PAD;
                        if iy < 0 || ix < 0 || iy >= h as i64 || ix >= w as i64 {
                            continue;
                        }
                        acc += x.data[bi * h * w + iy as usize * w + ix as usize];
                        count += 1;
                    }
                }
                data.push(acc / count.max(1) as f32);
            }
        }
    }
    Tensor::new(vec![b, out_h, out_w], DType::F32, data)
}

/// First index of each row's maximum, as an integer-valued tensor — the
/// reference for the `argmax_rows` fixture. The row maximum folds left to
/// right in `f32`, matching the oracle's `reduce` order, so the selected
/// index is bit-exact.
pub fn argmax_rows_ref(x: &Tensor) -> Tensor {
    let cols = *x.shape.last().expect("argmax_rows on rank-0");
    let rows = x.numel() / cols;
    let mut data = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &x.data[r * cols..(r + 1) * cols];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let idx = row.iter().position(|&v| v == m).unwrap_or(0);
        data.push(idx as f32);
    }
    Tensor::new(vec![rows], DType::I32, data)
}

/// Sliding-window sum of width 4 along the last axis — the reference for
/// the `window_sum` fixture's `fori_loop` + `dynamic-slice` lowering.
/// Accumulates in the loop's order (slice 0 first) so results are
/// bit-exact against the oracle.
pub fn window_sum_ref(x: &Tensor) -> Tensor {
    const W: usize = 4;
    let (rows, cols) = (x.shape[0], x.shape[1]);
    let out_cols = cols - W + 1;
    let mut data = vec![0.0f32; rows * out_cols];
    for i in 0..W {
        for r in 0..rows {
            for c in 0..out_cols {
                data[r * out_cols + c] += x.data[r * cols + c + i];
            }
        }
    }
    Tensor::new(vec![rows, out_cols], DType::F32, data)
}

/// Deterministic pseudo-random input for fixture `name` (shapes mirror
/// the `python/compile/model.py` manifest).
pub fn fixture_input(name: &str, seed: u64) -> Option<Tensor> {
    let dims: Vec<usize> = match name {
        "avgpool2d_pad" => vec![8, 32, 32],
        "argmax_rows" => vec![64, 128],
        "window_sum" => vec![128, 256],
        _ => return None,
    };
    let n = dims.iter().product();
    let mut rng = XorShiftRng::new(0xF1C7_0000 ^ seed);
    Some(Tensor::new(dims, DType::F32, rng.normal_vec(n)))
}

/// Cross-check one extra fixture against its Rust reference. Returns
/// `Err` with a human-readable detail on load/exec failure or numeric
/// mismatch; `name` must be one of [`EXTRA_FIXTURES`].
pub fn cross_check_fixture(reg: &OracleRegistry, name: &str, seed: u64) -> Result<(), String> {
    let x = fixture_input(name, seed).ok_or_else(|| format!("unknown extra fixture '{name}'"))?;
    let want = match name {
        "avgpool2d_pad" => avgpool2d_pad_ref(&x),
        "argmax_rows" => argmax_rows_ref(&x),
        "window_sum" => window_sum_ref(&x),
        _ => unreachable!("fixture_input validated the name"),
    };
    let oracle = reg.get(name).map_err(|e| format!("load failed: {e}"))?;
    let got = oracle.run(&[&x]).map_err(|e| format!("exec failed: {e}"))?;
    if got.len() != 1 {
        return Err(format!("oracle returned {} outputs, expected 1", got.len()));
    }
    // argmax indices must match exactly; the float fixtures accumulate in
    // the oracle's own order, so they are bit-exact too — a tiny tolerance
    // keeps the check robust to platform libm differences in the inputs
    let (rtol, atol) = if name == "argmax_rows" { (0.0, 0.0) } else { (1e-6, 1e-7) };
    let rep = allclose_report(&got[0], &want, rtol, atol);
    if !rep.ok {
        return Err(rep.summary());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avgpool_reference_counts_exclude_padding() {
        // 1x2x2 input, window 3 stride 2 pad 1: single output = mean of
        // all 4 in-bounds elements
        let x = Tensor::new(vec![1, 2, 2], DType::F32, vec![1.0, 2.0, 3.0, 4.0]);
        let y = avgpool2d_pad_ref(&x);
        assert_eq!(y.shape, vec![1, 1, 1]);
        assert!((y.data[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn argmax_reference_picks_first_max() {
        let x = Tensor::new(vec![2, 4], DType::F32, vec![1., 5., 5., 2., -1., -1., -3., -1.]);
        let y = argmax_rows_ref(&x);
        assert_eq!(y.data, vec![1.0, 0.0]);
    }

    #[test]
    fn window_sum_reference_is_a_width_4_sliding_sum() {
        let x = Tensor::new(vec![1, 6], DType::F32, vec![1., 2., 3., 4., 5., 6.]);
        let y = window_sum_ref(&x);
        assert_eq!(y.shape, vec![1, 3]);
        assert_eq!(y.data, vec![10., 14., 18.]);
    }

    #[test]
    fn fixture_inputs_are_deterministic() {
        let a = fixture_input("argmax_rows", 3).unwrap();
        let b = fixture_input("argmax_rows", 3).unwrap();
        assert_eq!(a.data, b.data);
        assert!(fixture_input("nonesuch", 3).is_none());
    }
}
