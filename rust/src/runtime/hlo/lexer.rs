//! Line tokenizer for the HLO text format. The printer emits one
//! instruction per line, so lexing is per-line: words (identifiers,
//! numbers, shape element types — anything that is not punctuation),
//! quoted strings (metadata op names), and the punctuation that carries
//! structure (`= , ( ) { } [ ]`). `/* ... */` comments are skipped.

/// One token of an instruction line.
#[derive(Clone, Debug, PartialEq)]
pub enum Token {
    /// Identifier / number / keyword — e.g. `reduce-window.9`, `f32`,
    /// `-inf`, `0_0x2047_0`. Leading `%` (newer HLO printers prefix
    /// instruction names) is stripped.
    Word(String),
    /// Double-quoted string (escapes preserved verbatim).
    Str(String),
    /// One of `= , ( ) { } [ ]`.
    Punct(char),
}

impl Token {
    /// Human-readable form for parser error messages.
    pub fn describe(&self) -> String {
        match self {
            Token::Word(w) => format!("'{w}'"),
            Token::Str(s) => format!("\"{s}\""),
            Token::Punct(c) => format!("'{c}'"),
        }
    }
}

fn is_punct(c: char) -> bool {
    matches!(c, '=' | ',' | '(' | ')' | '{' | '}' | '[' | ']')
}

/// Tokenize one line. Returns an error message (no position — the parser
/// attaches the line number) on unterminated strings or comments.
pub fn lex_line(line: &str) -> Result<Vec<Token>, String> {
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '"' {
            let mut s = String::new();
            let mut j = i + 1;
            loop {
                if j >= n {
                    return Err("unterminated string literal".to_string());
                }
                if chars[j] == '\\' && j + 1 < n {
                    s.push(chars[j]);
                    s.push(chars[j + 1]);
                    j += 2;
                    continue;
                }
                if chars[j] == '"' {
                    break;
                }
                s.push(chars[j]);
                j += 1;
            }
            toks.push(Token::Str(s));
            i = j + 1;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut j = i + 2;
            loop {
                if j + 1 >= n {
                    return Err("unterminated /* comment".to_string());
                }
                if chars[j] == '*' && chars[j + 1] == '/' {
                    break;
                }
                j += 1;
            }
            i = j + 2;
            continue;
        }
        if is_punct(c) {
            toks.push(Token::Punct(c));
            i += 1;
            continue;
        }
        let mut j = i;
        while j < n && !chars[j].is_whitespace() && !is_punct(chars[j]) && chars[j] != '"' {
            j += 1;
        }
        let word: String = chars[i..j].iter().collect();
        let word = word.strip_prefix('%').unwrap_or(&word).to_string();
        toks.push(Token::Word(word));
        i = j;
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn words(line: &str) -> Vec<Token> {
        lex_line(line).unwrap()
    }

    #[test]
    fn instruction_line_tokenizes() {
        let t = words("  reduce.8 = f32[8]{0} reduce(Arg_0.1, constant.3), dimensions={1}, to_apply=region_0.4");
        assert_eq!(t[0], Token::Word("reduce.8".into()));
        assert_eq!(t[1], Token::Punct('='));
        assert_eq!(t[2], Token::Word("f32".into()));
        assert_eq!(t[3], Token::Punct('['));
        assert!(t.contains(&Token::Word("to_apply".into())));
        assert!(t.contains(&Token::Word("region_0.4".into())));
    }

    #[test]
    fn negative_and_special_numbers_are_single_words() {
        let t = words("constant.3 = f32[] constant(-inf)");
        assert!(t.contains(&Token::Word("-inf".into())));
        let t = words("constant.9 = f32[] constant(1e-05)");
        assert!(t.contains(&Token::Word("1e-05".into())));
    }

    #[test]
    fn percent_prefix_is_stripped() {
        let t = words("%add.1 = f32[] add(%a, %b)");
        assert_eq!(t[0], Token::Word("add.1".into()));
        assert!(t.contains(&Token::Word("a".into())));
    }

    #[test]
    fn quoted_strings_and_comments() {
        let t = words("call.1 = f32[] call(x), /* skipped */ custom=\"a, b\"");
        assert!(t.contains(&Token::Str("a, b".into())));
        assert!(!t.iter().any(|tk| matches!(tk, Token::Word(w) if w.contains("skipped"))));
    }

    #[test]
    fn unterminated_string_errors() {
        assert!(lex_line("x = \"oops").is_err());
    }
}
