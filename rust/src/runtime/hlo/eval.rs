//! Reference tree-walking evaluator for parsed HLO modules over [`Tensor`]
//! values. The production oracle path compiles modules once into
//! [`super::plan::ExecutablePlan`]; this evaluator defines the reference
//! semantics the plan must reproduce bit-for-bit (see
//! `rust/tests/plan_differential.rs`) and serves as the fallback for
//! modules outside the plan compiler's op set.
//!
//! The op set is the subset the `python/compile/model.py` manifest lowers
//! to (see `docs/HLO_SUBSET.md` for the authoritative spec): elementwise
//! arithmetic, `broadcast`/`reshape`/`transpose`, `iota`, `dynamic-slice`,
//! `reduce` and `reduce-window` (with a prefix-scan fast path so `cumsum`
//! stays O(n)), `dot` (general batched contraction), `select`/`compare`,
//! `convert`, `call`, `tuple`/`get-tuple-element`, and `while` over a
//! tuple-shaped carried state (how `lax.fori_loop` lowers).
//!
//! All host data is `f32` (pred values are 0.0 / 1.0), matching the rest
//! of the pipeline; the logical element type of each result is carried on
//! [`Tensor::dtype`], and `convert` models the numeric effect of dtype
//! changes (truncation to integers, `x != 0` to pred, f16/bf16
//! quantization). Sum/product reductions accumulate in `f64` (oracle
//! grade — a reduce can span millions of elements); the prefix-scan fast
//! path stays `f32` so cumulative sums reproduce the references' running
//! f32 accumulation exactly. Agreement with the Rust references is judged
//! by the tasks' rtol/atol, not bit equality.

use super::parser::{CmpDir, Computation, Instr, Module, Opcode, Shape};
use super::MAX_WHILE_ITERS;
use crate::util::tensor::{DType, Tensor};

/// An evaluated instruction result: a dense tensor, or a flat tuple of
/// tensors (entry roots, `while` carried state, tuple-returning calls).
/// Nested tuples are outside the supported corpus.
#[derive(Clone, Debug)]
pub enum Value {
    Tensor(Tensor),
    Tuple(Vec<Tensor>),
}

/// Execute the module's ENTRY computation on the given inputs.
/// Outputs are the flattened root tuple (or the single root tensor).
pub fn evaluate(m: &Module, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
    let comp = m.entry_computation();
    if inputs.len() != comp.params.len() {
        return Err(format!(
            "entry computation '{}' takes {} parameters, got {} inputs",
            comp.name,
            comp.params.len(),
            inputs.len()
        ));
    }
    for (pi, &idx) in comp.params.iter().enumerate() {
        let ins = &comp.instrs[idx];
        let want = ins.shape.array().map_err(|e| format!("{}: {e}", ins.name))?;
        if want.dims != inputs[pi].shape {
            return Err(format!(
                "parameter {pi} expects shape {want}, got input shape {:?}",
                inputs[pi].shape
            ));
        }
    }
    let args: Vec<Value> = inputs.iter().map(|t| Value::Tensor((*t).clone())).collect();
    match eval_computation(m, m.entry, args)? {
        Value::Tuple(ts) => Ok(ts),
        Value::Tensor(t) => Ok(vec![t]),
    }
}

fn eval_computation(m: &Module, ci: usize, args: Vec<Value>) -> Result<Value, String> {
    let comp = &m.computations[ci];
    if args.len() != comp.params.len() {
        return Err(format!(
            "computation '{}' takes {} arguments, got {}",
            comp.name,
            comp.params.len(),
            args.len()
        ));
    }
    // free each value after its last use: entry computations hold
    // multi-megabyte tensors per instruction, and without this the peak
    // footprint is O(instructions × tensor size)
    let mut last_use = vec![usize::MAX; comp.instrs.len()];
    for (i, ins) in comp.instrs.iter().enumerate() {
        for &o in &ins.operands {
            last_use[o] = i;
        }
    }
    last_use[comp.root] = usize::MAX;
    let mut env: Vec<Option<Value>> = (0..comp.instrs.len()).map(|_| None).collect();
    for (arg, &idx) in args.into_iter().zip(&comp.params) {
        env[idx] = Some(arg);
    }
    for i in 0..comp.instrs.len() {
        if env[i].is_none() {
            let v = eval_instr(m, comp, i, &env)?;
            env[i] = Some(v);
        }
        for &o in &comp.instrs[i].operands {
            if last_use[o] == i && o != comp.root {
                env[o] = None;
            }
        }
    }
    env[comp.root]
        .take()
        .ok_or_else(|| format!("computation '{}': root was never evaluated", comp.name))
}

fn operand<'a>(env: &'a [Option<Value>], ins: &Instr, k: usize) -> Result<&'a Tensor, String> {
    let idx = match ins.operands.get(k) {
        Some(&i) => i,
        None => return Err(format!("{}: missing operand {k}", ins.name)),
    };
    match env.get(idx).and_then(|v| v.as_ref()) {
        Some(Value::Tensor(t)) => Ok(t),
        Some(Value::Tuple(_)) => {
            Err(format!("{}: tuple-valued operands are not supported", ins.name))
        }
        None => Err(format!("{}: operand evaluated out of order", ins.name)),
    }
}

fn out_shape<'a>(ins: &'a Instr) -> Result<&'a Shape, String> {
    ins.shape.array().map_err(|e| format!("{}: {e}", ins.name))
}

fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

fn unary(ins: &Instr, x: &Tensor, f: impl Fn(f32) -> f32) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    if shape.numel() != x.numel() {
        return Err(format!("{}: result shape {shape} vs operand numel {}", ins.name, x.numel()));
    }
    Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), x.data.iter().map(|&v| f(v)).collect()))
}

fn binary(
    ins: &Instr,
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32,
) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    if a.numel() != b.numel() || shape.numel() != a.numel() {
        return Err(format!(
            "{}: operand shapes {:?} / {:?} do not match result {shape}",
            ins.name, a.shape, b.shape
        ));
    }
    let data = a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect();
    Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), data))
}

/// Permute `t`'s axes: output dim `d` takes input dim `perm[d]`.
fn permute(t: &Tensor, perm: &[usize]) -> Result<Tensor, String> {
    let rank = t.rank();
    if perm.len() != rank {
        return Err(format!("permutation {perm:?} does not match rank {rank}"));
    }
    let mut seen = vec![false; rank];
    for &p in perm {
        if p >= rank || seen[p] {
            return Err(format!("invalid permutation {perm:?} for rank {rank}"));
        }
        seen[p] = true;
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| t.shape[p]).collect();
    let in_strides = t.strides();
    let ostr = row_major_strides(&out_dims);
    let n = t.numel();
    let mut out = vec![0f32; n];
    for (li, slot) in out.iter_mut().enumerate() {
        let mut src = 0usize;
        for d in 0..rank {
            let idx = (li / ostr[d]) % out_dims[d];
            src += idx * in_strides[perm[d]];
        }
        *slot = t.data[src];
    }
    Ok(Tensor::new(out_dims, DType::F32, out))
}

/// Reduce / reduce-window combining function. `Generic` falls back to
/// interpreting the combiner computation per element pair (correct but
/// slow — only exotic combiners take it).
enum Combiner {
    Add,
    Mul,
    Max,
    Min,
    Generic(usize),
}

fn combiner_of(m: &Module, ins: &Instr) -> Result<Combiner, String> {
    let name = match ins.to_apply.as_deref() {
        Some(n) => n,
        None => return Err(format!("{}: reduce without to_apply", ins.name)),
    };
    let ci = match m.computation_index(name) {
        Some(i) => i,
        None => return Err(format!("{}: unknown combiner computation '{name}'", ins.name)),
    };
    let comp = &m.computations[ci];
    let root = &comp.instrs[comp.root];
    if comp.params.len() == 2 && root.operands.len() == 2 {
        let (p0, p1) = (comp.params[0], comp.params[1]);
        let (a, b) = (root.operands[0], root.operands[1]);
        if (a == p0 && b == p1) || (a == p1 && b == p0) {
            match root.opcode {
                Opcode::Add => return Ok(Combiner::Add),
                Opcode::Multiply => return Ok(Combiner::Mul),
                Opcode::Maximum => return Ok(Combiner::Max),
                Opcode::Minimum => return Ok(Combiner::Min),
                _ => {}
            }
        }
    }
    Ok(Combiner::Generic(ci))
}

fn apply_combiner(m: &Module, c: &Combiner, acc: f32, v: f32) -> Result<f32, String> {
    Ok(match c {
        Combiner::Add => acc + v,
        Combiner::Mul => acc * v,
        Combiner::Max => acc.max(v),
        Combiner::Min => acc.min(v),
        Combiner::Generic(ci) => {
            let args = vec![
                Value::Tensor(Tensor::new(vec![], DType::F32, vec![acc])),
                Value::Tensor(Tensor::new(vec![], DType::F32, vec![v])),
            ];
            match eval_computation(m, *ci, args)? {
                Value::Tensor(t) => t.data[0],
                Value::Tuple(_) => return Err("combiner returned a tuple".to_string()),
            }
        }
    })
}

fn scalar_init(ins: &Instr, t: &Tensor) -> Result<f32, String> {
    if t.numel() != 1 {
        return Err(format!("{}: init value must be scalar, got shape {:?}", ins.name, t.shape));
    }
    Ok(t.data[0])
}

fn eval_broadcast(ins: &Instr, x: &Tensor) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    let out_dims = shape.dims.clone();
    let dt = shape.elem.dtype();
    let n = shape.numel();
    // scalar fill fast path (the dominant case: constants broadcast over
    // multi-megabyte elementwise tensors)
    if x.numel() == 1 {
        return Ok(Tensor::new(out_dims, dt, vec![x.data[0]; n]));
    }
    let dims = ins.dimensions.clone().unwrap_or_default();
    if dims.len() != x.rank() {
        return Err(format!(
            "{}: dimensions {dims:?} do not match operand rank {}",
            ins.name,
            x.rank()
        ));
    }
    let in_strides = x.strides();
    let mut stride_for_out = vec![0usize; out_dims.len()];
    for (i, &od) in dims.iter().enumerate() {
        if od >= out_dims.len() {
            return Err(format!("{}: broadcast dimension {od} out of range", ins.name));
        }
        if x.shape[i] != 1 {
            if x.shape[i] != out_dims[od] {
                return Err(format!(
                    "{}: operand dim {i} ({}) does not match output dim {od} ({})",
                    ins.name, x.shape[i], out_dims[od]
                ));
            }
            stride_for_out[od] = in_strides[i];
        }
    }
    let ostr = row_major_strides(&out_dims);
    let mut out = vec![0f32; n];
    for (li, slot) in out.iter_mut().enumerate() {
        let mut src = 0usize;
        for d in 0..out_dims.len() {
            let idx = (li / ostr[d]) % out_dims[d];
            src += idx * stride_for_out[d];
        }
        *slot = x.data[src];
    }
    Ok(Tensor::new(out_dims, dt, out))
}

fn eval_reduce(m: &Module, ins: &Instr, x: &Tensor, init: f32) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    let comb = combiner_of(m, ins)?;
    let red = match &ins.dimensions {
        Some(d) => d.clone(),
        None => return Err(format!("{}: reduce without dimensions", ins.name)),
    };
    let in_dims = &x.shape;
    let kept: Vec<usize> = (0..in_dims.len()).filter(|d| !red.contains(d)).collect();
    let kept_dims: Vec<usize> = kept.iter().map(|&d| in_dims[d]).collect();
    if kept_dims != shape.dims {
        return Err(format!(
            "{}: reduce output shape {shape} does not match kept dims {kept_dims:?}",
            ins.name
        ));
    }
    let istr = row_major_strides(in_dims);
    let ostr = row_major_strides(&shape.dims);
    let oi_of = |li: usize| {
        let mut oi = 0usize;
        for (j, &d) in kept.iter().enumerate() {
            let idx = (li / istr[d]) % in_dims[d];
            oi += idx * ostr[j];
        }
        oi
    };
    // Sum/product reductions accumulate in f64: a reduce can span millions
    // of elements (mse_loss reduces 4.2M), and a naive f32 chain drifts
    // past the tasks' tolerances — the Rust references accumulate wide for
    // exactly the same reason (tensor::mean_all). max/min are exact in f32.
    let out = match comb {
        Combiner::Add | Combiner::Mul => {
            let mul = matches!(comb, Combiner::Mul);
            let mut acc = vec![init as f64; shape.numel()];
            for (li, &v) in x.data.iter().enumerate() {
                let oi = oi_of(li);
                if mul {
                    acc[oi] *= v as f64;
                } else {
                    acc[oi] += v as f64;
                }
            }
            acc.into_iter().map(|v| v as f32).collect()
        }
        _ => {
            let mut out = vec![init; shape.numel()];
            for (li, &v) in x.data.iter().enumerate() {
                let oi = oi_of(li);
                out[oi] = apply_combiner(m, &comb, out[oi], v)?;
            }
            out
        }
    };
    Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out))
}

fn eval_reduce_window(m: &Module, ins: &Instr, x: &Tensor, init: f32) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    let comb = combiner_of(m, ins)?;
    let w = match &ins.window {
        Some(w) => w,
        None => return Err(format!("{}: reduce-window without window attribute", ins.name)),
    };
    let in_dims = &x.shape;
    let rank = in_dims.len();
    if w.size.len() != rank || w.stride.len() != rank || w.pad.len() != rank {
        return Err(format!("{}: window rank does not match operand rank {rank}", ins.name));
    }

    // Prefix-scan fast path: every dim is either pointwise (size 1) or the
    // single scan dim (window covers the whole dim, padded so output i sees
    // elements 0..=i — or i.. for the reverse scan). This is how XLA
    // lowers cumsum/cumprod; the generic path below is O(n·window).
    let mut scan_dim: Option<(usize, bool)> = None;
    let mut scan_ok = shape.dims == *in_dims;
    if scan_ok {
        for d in 0..rank {
            let full = in_dims[d];
            if w.size[d] == 1 && w.stride[d] == 1 && w.pad[d] == (0, 0) {
                continue;
            }
            if w.stride[d] == 1 && full > 0 && w.size[d] == full && scan_dim.is_none() {
                if w.pad[d] == (full - 1, 0) {
                    scan_dim = Some((d, false));
                    continue;
                }
                if w.pad[d] == (0, full - 1) {
                    scan_dim = Some((d, true));
                    continue;
                }
            }
            scan_ok = false;
            break;
        }
    }
    if scan_ok {
        if let Some((sd, rev)) = scan_dim {
            let istr = row_major_strides(in_dims);
            let len = in_dims[sd];
            let sstride = istr[sd];
            let n = x.numel();
            let mut out = vec![0f32; n];
            for base in 0..n {
                if (base / sstride) % len != 0 {
                    continue;
                }
                let mut acc = init;
                if rev {
                    for j in (0..len).rev() {
                        let p = base + j * sstride;
                        acc = apply_combiner(m, &comb, acc, x.data[p])?;
                        out[p] = acc;
                    }
                } else {
                    for j in 0..len {
                        let p = base + j * sstride;
                        acc = apply_combiner(m, &comb, acc, x.data[p])?;
                        out[p] = acc;
                    }
                }
            }
            return Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out));
        }
    }

    // generic windowed reduction
    let istr = row_major_strides(in_dims);
    let ostr = row_major_strides(&shape.dims);
    let wstr = row_major_strides(&w.size);
    let win_n: usize = w.size.iter().product();
    let out_n = shape.numel();
    let mut out = vec![0f32; out_n];
    let mut starts = vec![0isize; rank];
    for (oi, slot) in out.iter_mut().enumerate() {
        for d in 0..rank {
            let idx = (oi / ostr[d]) % shape.dims[d];
            starts[d] = (idx * w.stride[d]) as isize - w.pad[d].0 as isize;
        }
        let mut acc = init;
        'window: for wi in 0..win_n {
            let mut li = 0usize;
            for d in 0..rank {
                let pos = starts[d] + ((wi / wstr[d]) % w.size[d]) as isize;
                if pos < 0 || pos >= in_dims[d] as isize {
                    continue 'window; // padding element: identity
                }
                li += pos as usize * istr[d];
            }
            acc = apply_combiner(m, &comb, acc, x.data[li])?;
        }
        *slot = acc;
    }
    Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out))
}

fn eval_dot(ins: &Instr, lhs: &Tensor, rhs: &Tensor) -> Result<Tensor, String> {
    let shape = out_shape(ins)?;
    let lb = &ins.lhs_batch;
    let rb = &ins.rhs_batch;
    let lc = &ins.lhs_contract;
    let rc = &ins.rhs_contract;
    if lb.len() != rb.len() || lc.len() != rc.len() {
        return Err(format!("{}: mismatched batch/contracting dimension counts", ins.name));
    }
    for (&ld, &rd) in lb.iter().zip(rb) {
        if lhs.shape[ld] != rhs.shape[rd] {
            return Err(format!(
                "{}: batch dims disagree (lhs dim {ld} = {}, rhs dim {rd} = {})",
                ins.name, lhs.shape[ld], rhs.shape[rd]
            ));
        }
    }
    for (&ld, &rd) in lc.iter().zip(rc) {
        if lhs.shape[ld] != rhs.shape[rd] {
            return Err(format!(
                "{}: contracting dims disagree (lhs dim {ld} = {}, rhs dim {rd} = {})",
                ins.name, lhs.shape[ld], rhs.shape[rd]
            ));
        }
    }
    let lfree: Vec<usize> =
        (0..lhs.rank()).filter(|d| !lb.contains(d) && !lc.contains(d)).collect();
    let rfree: Vec<usize> =
        (0..rhs.rank()).filter(|d| !rb.contains(d) && !rc.contains(d)).collect();
    let mut lperm = lb.clone();
    lperm.extend_from_slice(&lfree);
    lperm.extend_from_slice(lc);
    let mut rperm = rb.clone();
    rperm.extend_from_slice(rc);
    rperm.extend_from_slice(&rfree);
    let lt = permute(lhs, &lperm).map_err(|e| format!("{}: {e}", ins.name))?;
    let rt = permute(rhs, &rperm).map_err(|e| format!("{}: {e}", ins.name))?;
    let b: usize = lb.iter().map(|&d| lhs.shape[d]).product();
    let k: usize = lc.iter().map(|&d| lhs.shape[d]).product();
    let m_: usize = lfree.iter().map(|&d| lhs.shape[d]).product();
    let n_: usize = rfree.iter().map(|&d| rhs.shape[d]).product();
    if shape.numel() != b * m_ * n_ {
        return Err(format!(
            "{}: result shape {shape} does not match dot extents {b}x{m_}x{n_}",
            ins.name
        ));
    }
    let mut out = vec![0f32; b * m_ * n_];
    for bi in 0..b {
        for mi in 0..m_ {
            let lrow = (bi * m_ + mi) * k;
            let orow = (bi * m_ + mi) * n_;
            for ki in 0..k {
                let l = lt.data[lrow + ki];
                let rrow = (bi * k + ki) * n_;
                for ni in 0..n_ {
                    out[orow + ni] += l * rt.data[rrow + ni];
                }
            }
        }
    }
    Ok(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out))
}

fn eval_instr(
    m: &Module,
    comp: &Computation,
    i: usize,
    env: &[Option<Value>],
) -> Result<Value, String> {
    let ins = &comp.instrs[i];
    let t = |k: usize| operand(env, ins, k);
    let v = match &ins.opcode {
        Opcode::Parameter => {
            return Err(format!("{}: parameter was not bound to an argument", ins.name))
        }
        Opcode::Constant => {
            let shape = out_shape(ins)?;
            let lit = ins
                .literal
                .clone()
                .ok_or_else(|| format!("{}: constant without literal", ins.name))?;
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), lit))
        }
        Opcode::Add => Value::Tensor(binary(ins, t(0)?, t(1)?, |a, b| a + b)?),
        Opcode::Subtract => Value::Tensor(binary(ins, t(0)?, t(1)?, |a, b| a - b)?),
        Opcode::Multiply => Value::Tensor(binary(ins, t(0)?, t(1)?, |a, b| a * b)?),
        Opcode::Divide => Value::Tensor(binary(ins, t(0)?, t(1)?, |a, b| a / b)?),
        Opcode::Maximum => Value::Tensor(binary(ins, t(0)?, t(1)?, f32::max)?),
        Opcode::Minimum => Value::Tensor(binary(ins, t(0)?, t(1)?, f32::min)?),
        Opcode::Power => Value::Tensor(binary(ins, t(0)?, t(1)?, f32::powf)?),
        Opcode::Exponential => Value::Tensor(unary(ins, t(0)?, f32::exp)?),
        Opcode::Log => Value::Tensor(unary(ins, t(0)?, f32::ln)?),
        Opcode::Tanh => Value::Tensor(unary(ins, t(0)?, f32::tanh)?),
        Opcode::Sqrt => Value::Tensor(unary(ins, t(0)?, f32::sqrt)?),
        Opcode::Rsqrt => Value::Tensor(unary(ins, t(0)?, |x| 1.0 / x.sqrt())?),
        Opcode::Negate => Value::Tensor(unary(ins, t(0)?, |x| -x)?),
        Opcode::Abs => Value::Tensor(unary(ins, t(0)?, f32::abs)?),
        Opcode::Floor => Value::Tensor(unary(ins, t(0)?, f32::floor)?),
        Opcode::Ceil => Value::Tensor(unary(ins, t(0)?, f32::ceil)?),
        Opcode::Sign => Value::Tensor(unary(ins, t(0)?, |x| {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                x // preserves ±0 and NaN like HLO sign
            }
        })?),
        Opcode::Logistic => Value::Tensor(unary(ins, t(0)?, |x| 1.0 / (1.0 + (-x).exp()))?),
        Opcode::Copy | Opcode::Reshape => {
            let x = t(0)?;
            let shape = out_shape(ins)?;
            if shape.numel() != x.numel() {
                return Err(format!(
                    "{}: cannot reshape {} elements into {shape}",
                    ins.name,
                    x.numel()
                ));
            }
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), x.data.clone()))
        }
        Opcode::Convert => {
            let x = t(0)?;
            let shape = out_shape(ins)?;
            if shape.numel() != x.numel() {
                return Err(format!(
                    "{}: cannot convert {} elements into {shape}",
                    ins.name,
                    x.numel()
                ));
            }
            let src_elem = comp.instrs[ins.operands[0]]
                .shape
                .array()
                .map_err(|e| format!("{}: {e}", ins.name))?
                .elem;
            let data = match super::convert_op(src_elem, shape.elem) {
                None => x.data.clone(),
                Some(op) => x.data.iter().map(|&v| op.apply(v)).collect(),
            };
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), data))
        }
        Opcode::Iota => {
            let shape = out_shape(ins)?;
            let dim = ins
                .iota_dim
                .ok_or_else(|| format!("{}: iota without iota_dimension", ins.name))?;
            if dim >= shape.dims.len() {
                return Err(format!(
                    "{}: iota_dimension {dim} out of range for {shape}",
                    ins.name
                ));
            }
            let ostr = row_major_strides(&shape.dims);
            let mut out = vec![0f32; shape.numel()];
            for (li, slot) in out.iter_mut().enumerate() {
                *slot = ((li / ostr[dim]) % shape.dims[dim]) as f32;
            }
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out))
        }
        Opcode::DynamicSlice => {
            let x = t(0)?;
            let shape = out_shape(ins)?;
            let rank = x.rank();
            if ins.slice_sizes.len() != rank {
                return Err(format!(
                    "{}: dynamic_slice_sizes rank does not match operand rank {rank}",
                    ins.name
                ));
            }
            if shape.dims != ins.slice_sizes {
                return Err(format!(
                    "{}: result shape {shape} does not match dynamic_slice_sizes {:?}",
                    ins.name, ins.slice_sizes
                ));
            }
            if ins.operands.len() != rank + 1 {
                return Err(format!(
                    "{}: expected {} start indices, found {}",
                    ins.name,
                    rank,
                    ins.operands.len().saturating_sub(1)
                ));
            }
            let istr = x.strides();
            let mut base = 0usize;
            for d in 0..rank {
                let idx_t = t(1 + d)?;
                if idx_t.numel() != 1 {
                    return Err(format!("{}: start index {d} must be scalar", ins.name));
                }
                if ins.slice_sizes[d] > x.shape[d] {
                    return Err(format!(
                        "{}: slice size {} exceeds operand dim {} ({})",
                        ins.name, ins.slice_sizes[d], d, x.shape[d]
                    ));
                }
                // starts clamp into [0, dim - size], per HLO semantics
                let max_start = (x.shape[d] - ins.slice_sizes[d]) as i64;
                let start = (idx_t.data[0] as i64).clamp(0, max_start);
                base += start as usize * istr[d];
            }
            let ostr = row_major_strides(&shape.dims);
            let mut out = vec![0f32; shape.numel()];
            for (li, slot) in out.iter_mut().enumerate() {
                let mut si = base;
                for d in 0..rank {
                    si += ((li / ostr[d]) % shape.dims[d]) * istr[d];
                }
                *slot = x.data[si];
            }
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), out))
        }
        Opcode::Compare => {
            let dir = ins
                .direction
                .ok_or_else(|| format!("{}: compare without direction", ins.name))?;
            let f: fn(f32, f32) -> bool = match dir {
                CmpDir::Eq => |a, b| a == b,
                CmpDir::Ne => |a, b| a != b,
                CmpDir::Ge => |a, b| a >= b,
                CmpDir::Gt => |a, b| a > b,
                CmpDir::Le => |a, b| a <= b,
                CmpDir::Lt => |a, b| a < b,
            };
            Value::Tensor(binary(ins, t(0)?, t(1)?, move |a, b| if f(a, b) { 1.0 } else { 0.0 })?)
        }
        Opcode::Select => {
            let pred = t(0)?;
            let on_true = t(1)?;
            let on_false = t(2)?;
            let shape = out_shape(ins)?;
            if pred.numel() != shape.numel()
                || on_true.numel() != shape.numel()
                || on_false.numel() != shape.numel()
            {
                return Err(format!("{}: select operand shapes disagree", ins.name));
            }
            let data = pred
                .data
                .iter()
                .zip(&on_true.data)
                .zip(&on_false.data)
                .map(|((&p, &a), &b)| if p != 0.0 { a } else { b })
                .collect();
            Value::Tensor(Tensor::new(shape.dims.clone(), shape.elem.dtype(), data))
        }
        Opcode::Transpose => {
            let x = t(0)?;
            let perm = ins
                .dimensions
                .clone()
                .ok_or_else(|| format!("{}: transpose without dimensions", ins.name))?;
            let out = permute(x, &perm).map_err(|e| format!("{}: {e}", ins.name))?;
            let shape = out_shape(ins)?;
            if out.shape != shape.dims {
                return Err(format!(
                    "{}: transpose produced {:?}, declared {shape}",
                    ins.name, out.shape
                ));
            }
            Value::Tensor(out.with_dtype(shape.elem.dtype()))
        }
        Opcode::Broadcast => Value::Tensor(eval_broadcast(ins, t(0)?)?),
        Opcode::Reduce => {
            let init = scalar_init(ins, t(1)?)?;
            Value::Tensor(eval_reduce(m, ins, t(0)?, init)?)
        }
        Opcode::ReduceWindow => {
            let init = scalar_init(ins, t(1)?)?;
            Value::Tensor(eval_reduce_window(m, ins, t(0)?, init)?)
        }
        Opcode::Dot => Value::Tensor(eval_dot(ins, t(0)?, t(1)?)?),
        Opcode::Call => {
            let target = ins
                .to_apply
                .as_deref()
                .ok_or_else(|| format!("{}: call without to_apply", ins.name))?;
            let ci = m
                .computation_index(target)
                .ok_or_else(|| format!("{}: unknown computation '{target}'", ins.name))?;
            let mut args = Vec::with_capacity(ins.operands.len());
            for k in 0..ins.operands.len() {
                args.push(Value::Tensor(t(k)?.clone()));
            }
            eval_computation(m, ci, args)?
        }
        Opcode::Tuple => {
            let mut ts = Vec::with_capacity(ins.operands.len());
            for k in 0..ins.operands.len() {
                ts.push(t(k)?.clone());
            }
            Value::Tuple(ts)
        }
        Opcode::GetTupleElement => {
            let k = ins
                .tuple_index
                .ok_or_else(|| format!("{}: get-tuple-element without index", ins.name))?;
            let oi = *ins
                .operands
                .first()
                .ok_or_else(|| format!("{}: missing operand 0", ins.name))?;
            match env.get(oi).and_then(|v| v.as_ref()) {
                Some(Value::Tuple(ts)) => Value::Tensor(ts.get(k).cloned().ok_or_else(|| {
                    format!("{}: tuple index {k} out of range ({} elements)", ins.name, ts.len())
                })?),
                Some(Value::Tensor(_)) => {
                    return Err(format!("{}: operand is not tuple-valued", ins.name))
                }
                None => return Err(format!("{}: operand evaluated out of order", ins.name)),
            }
        }
        Opcode::While => {
            let cond_name = ins
                .condition
                .as_deref()
                .ok_or_else(|| format!("{}: while without condition", ins.name))?;
            let body_name = ins
                .body
                .as_deref()
                .ok_or_else(|| format!("{}: while without body", ins.name))?;
            let cci = m
                .computation_index(cond_name)
                .ok_or_else(|| format!("{}: unknown computation '{cond_name}'", ins.name))?;
            let bci = m
                .computation_index(body_name)
                .ok_or_else(|| format!("{}: unknown computation '{body_name}'", ins.name))?;
            let oi = *ins
                .operands
                .first()
                .ok_or_else(|| format!("{}: missing operand 0", ins.name))?;
            let mut state = env
                .get(oi)
                .and_then(|v| v.as_ref())
                .cloned()
                .ok_or_else(|| format!("{}: operand evaluated out of order", ins.name))?;
            let mut iters = 0usize;
            loop {
                // the condition call clones the carried state because
                // eval_computation consumes its arguments (that is what
                // drives its last-use freeing); this is the reference /
                // fallback path, where simplicity beats the copy cost —
                // the plan executor is the fast path
                let keep = match eval_computation(m, cci, vec![state.clone()])? {
                    Value::Tensor(c) if c.numel() == 1 => c.data[0] != 0.0,
                    _ => {
                        return Err(format!(
                            "{}: condition '{cond_name}' must return a scalar pred",
                            ins.name
                        ))
                    }
                };
                if !keep {
                    break;
                }
                state = eval_computation(m, bci, vec![state])?;
                iters += 1;
                if iters >= MAX_WHILE_ITERS {
                    return Err(format!(
                        "{}: exceeded {MAX_WHILE_ITERS} while iterations",
                        ins.name
                    ));
                }
            }
            state
        }
        Opcode::Other(op) => {
            return Err(format!(
                "{}: opcode '{op}' is outside the interpreter's op set (see runtime/hlo/eval.rs)",
                ins.name
            ))
        }
    };
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::hlo::parser::parse_module;
    use crate::util::compare::allclose;

    fn run1(text: &str, inputs: &[&Tensor]) -> Tensor {
        let m = parse_module(text).unwrap();
        let mut out = evaluate(&m, inputs).unwrap();
        assert_eq!(out.len(), 1);
        out.remove(0)
    }

    fn t(data: &[f32]) -> Tensor {
        Tensor::from_vec(data.to_vec())
    }

    #[test]
    fn elementwise_binaries() {
        let cases = [
            ("add", vec![4.0, 6.0]),
            ("subtract", vec![-2.0, -2.0]),
            ("multiply", vec![3.0, 8.0]),
            ("divide", vec![1.0 / 3.0, 0.5]),
            ("maximum", vec![3.0, 4.0]),
            ("minimum", vec![1.0, 2.0]),
            ("power", vec![1.0, 16.0]),
        ];
        for (op, want) in cases {
            let text = format!(
                "HloModule t\n\nENTRY e {{\n  a = f32[2]{{0}} parameter(0)\n  b = f32[2]{{0}} parameter(1)\n  ROOT r = f32[2]{{0}} {op}(a, b)\n}}\n"
            );
            let got = run1(&text, &[&t(&[1.0, 2.0]), &t(&[3.0, 4.0])]);
            assert!(allclose(&got, &t(&want), 1e-6, 1e-7), "{op}: {:?} vs {want:?}", got.data);
        }
    }

    #[test]
    fn elementwise_unaries() {
        let x = [0.5f32, -1.25];
        let cases: Vec<(&str, Vec<f32>)> = vec![
            ("exponential", x.iter().map(|v| v.exp()).collect()),
            ("tanh", x.iter().map(|v| v.tanh()).collect()),
            ("negate", x.iter().map(|v| -v).collect()),
            ("abs", x.iter().map(|v| v.abs()).collect()),
            ("floor", x.iter().map(|v| v.floor()).collect()),
            ("sign", vec![1.0, -1.0]),
            ("logistic", x.iter().map(|v| 1.0 / (1.0 + (-v).exp())).collect()),
        ];
        for (op, want) in cases {
            let text = format!(
                "HloModule t\n\nENTRY e {{\n  a = f32[2]{{0}} parameter(0)\n  ROOT r = f32[2]{{0}} {op}(a)\n}}\n"
            );
            let got = run1(&text, &[&t(&x)]);
            assert!(allclose(&got, &t(&want), 1e-6, 1e-7), "{op}: {:?} vs {want:?}", got.data);
        }
    }

    #[test]
    fn sqrt_rsqrt_log() {
        let x = t(&[4.0, 0.25]);
        let text = "HloModule t\n\nENTRY e {\n  a = f32[2]{0} parameter(0)\n  s = f32[2]{0} sqrt(a)\n  r = f32[2]{0} rsqrt(a)\n  l = f32[2]{0} log(a)\n  ROOT o = (f32[2], f32[2], f32[2]) tuple(s, r, l)\n}\n";
        let m = parse_module(text).unwrap();
        let out = evaluate(&m, &[&x]).unwrap();
        assert!(allclose(&out[0], &t(&[2.0, 0.5]), 1e-6, 1e-7));
        assert!(allclose(&out[1], &t(&[0.5, 2.0]), 1e-6, 1e-7));
        assert!(allclose(&out[2], &t(&[4.0f32.ln(), 0.25f32.ln()]), 1e-6, 1e-7));
    }

    #[test]
    fn broadcast_scalar_and_row() {
        let text = "HloModule t\n\nENTRY e {\n  c = f32[] constant(2.5)\n  ROOT b = f32[2,3]{1,0} broadcast(c), dimensions={}\n}\n";
        let got = run1(text, &[]);
        assert_eq!(got.shape, vec![2, 3]);
        assert!(got.data.iter().all(|&v| v == 2.5));

        // row vector broadcast along dim 0 (jax keepdims pattern)
        let text = "HloModule t\n\nENTRY e {\n  r = f32[2]{0} parameter(0)\n  ROOT b = f32[2,3]{1,0} broadcast(r), dimensions={0}\n}\n";
        let got = run1(text, &[&t(&[1.0, 2.0])]);
        assert_eq!(got.data, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);

        // column broadcast along dim 1
        let text = "HloModule t\n\nENTRY e {\n  r = f32[3]{0} parameter(0)\n  ROOT b = f32[2,3]{1,0} broadcast(r), dimensions={1}\n}\n";
        let got = run1(text, &[&t(&[1.0, 2.0, 3.0])]);
        assert_eq!(got.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn reshape_and_transpose() {
        let x = Tensor::new(vec![2, 3], DType::F32, vec![1., 2., 3., 4., 5., 6.]);
        let text = "HloModule t\n\nENTRY e {\n  a = f32[2,3]{1,0} parameter(0)\n  ROOT r = f32[3,2]{1,0} transpose(a), dimensions={1,0}\n}\n";
        let got = run1(text, &[&x]);
        assert_eq!(got.shape, vec![3, 2]);
        assert_eq!(got.data, vec![1., 4., 2., 5., 3., 6.]);

        let text = "HloModule t\n\nENTRY e {\n  a = f32[2,3]{1,0} parameter(0)\n  ROOT r = f32[6]{0} reshape(a)\n}\n";
        let got = run1(text, &[&x]);
        assert_eq!(got.shape, vec![6]);
        assert_eq!(got.data, vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn reduce_add_and_max() {
        let x = Tensor::new(vec![2, 3], DType::F32, vec![1., 5., 2., -1., 0., 4.]);
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[2,3]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT red = f32[2]{0} reduce(x, z), dimensions={1}, to_apply=r\n}\n";
        let got = run1(text, &[&x]);
        assert!(allclose(&got, &t(&[8.0, 3.0]), 1e-6, 1e-7));

        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] maximum(a, b)\n}\n\nENTRY e {\n  x = f32[2,3]{1,0} parameter(0)\n  z = f32[] constant(-inf)\n  ROOT red = f32[3]{0} reduce(x, z), dimensions={0}, to_apply=r\n}\n";
        let got = run1(text, &[&x]);
        assert!(allclose(&got, &t(&[1.0, 5.0, 4.0]), 1e-6, 1e-7));
    }

    #[test]
    fn reduce_with_exotic_combiner_falls_back_to_interpreter() {
        // combiner computes a + 2*b: not a recognized monoid, exercises the
        // generic per-pair path
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  c = f32[] constant(2)\n  s = f32[] multiply(b, c)\n  ROOT o = f32[] add(a, s)\n}\n\nENTRY e {\n  x = f32[3]{0} parameter(0)\n  z = f32[] constant(0)\n  ROOT red = f32[]{} reduce(x, z), dimensions={0}, to_apply=r\n}\n";
        let got = run1(text, &[&t(&[1.0, 2.0, 3.0])]);
        assert_eq!(got.data, vec![12.0]);
    }

    #[test]
    fn dot_matmul_2d() {
        let a = Tensor::new(vec![2, 3], DType::F32, vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], DType::F32, vec![7., 8., 9., 10., 11., 12.]);
        let text = "HloModule t\n\nENTRY e {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  ROOT d = f32[2,2]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n}\n";
        let got = run1(text, &[&a, &b]);
        assert!(allclose(
            &got,
            &Tensor::new(vec![2, 2], DType::F32, vec![58., 64., 139., 154.]),
            1e-5,
            1e-6
        ));
    }

    #[test]
    fn dot_contract_first_dims_like_mhc_mixing() {
        // einsum('ji,jrd->ird') as lowered: contract dim 0 with dim 0
        let p = Tensor::new(vec![2, 2], DType::F32, vec![0.25, 0.75, 0.5, 0.5]);
        let h = Tensor::new(vec![2, 1, 2], DType::F32, vec![1., 2., 3., 4.]);
        let text = "HloModule t\n\nENTRY e {\n  p = f32[2,2]{1,0} parameter(0)\n  h = f32[2,1,2]{2,1,0} parameter(1)\n  ROOT d = f32[2,1,2]{2,1,0} dot(p, h), lhs_contracting_dims={0}, rhs_contracting_dims={0}\n}\n";
        let got = run1(text, &[&p, &h]);
        // out[i,r,d] = sum_j p[j,i] h[j,r,d]
        let want = Tensor::new(
            vec![2, 1, 2],
            DType::F32,
            vec![
                0.25 * 1. + 0.5 * 3.,
                0.25 * 2. + 0.5 * 4.,
                0.75 * 1. + 0.5 * 3.,
                0.75 * 2. + 0.5 * 4.,
            ],
        );
        assert!(allclose(&got, &want, 1e-5, 1e-6));
    }

    #[test]
    fn dot_rejects_equal_product_mismatched_dims() {
        // contracting dims [2,3] vs [3,2]: equal products, pairwise
        // mismatch — must error, not silently mis-contract
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 2]);
        let text = "HloModule t\n\nENTRY e {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  ROOT d = f32[]{} dot(a, b), lhs_contracting_dims={0,1}, rhs_contracting_dims={0,1}\n}\n";
        let m = parse_module(text).unwrap();
        let e = evaluate(&m, &[&a, &b]).unwrap_err();
        assert!(e.contains("contracting dims disagree"), "{e}");
    }

    #[test]
    fn compare_select_and_call() {
        // leaky-relu shaped module: where(x >= 0, x, 0.1*x) via call
        let text = "HloModule t\n\n_where.1 {\n  p = pred[4]{0} parameter(0)\n  a = f32[4]{0} parameter(1)\n  b = f32[4]{0} parameter(2)\n  ROOT s = f32[4]{0} select(p, a, b)\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  zero = f32[] constant(0)\n  zb = f32[4]{0} broadcast(zero), dimensions={}\n  c = pred[4]{0} compare(x, zb), direction=GE\n  tenth = f32[] constant(0.1)\n  tb = f32[4]{0} broadcast(tenth), dimensions={}\n  lo = f32[4]{0} multiply(x, tb)\n  ROOT w = f32[4]{0} call(c, x, lo), to_apply=_where.1\n}\n";
        let got = run1(text, &[&t(&[-2.0, -0.5, 0.0, 3.0])]);
        assert!(allclose(&got, &t(&[-0.2, -0.05, 0.0, 3.0]), 1e-6, 1e-7));
    }

    #[test]
    fn cumsum_scan_fast_path() {
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[2,4]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT w = f32[2,4]{1,0} reduce-window(x, z), window={size=1x4 pad=0_0x3_0}, to_apply=r\n}\n";
        let x = Tensor::new(vec![2, 4], DType::F32, vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let got = run1(text, &[&x]);
        assert!(allclose(
            &got,
            &Tensor::new(vec![2, 4], DType::F32, vec![1., 3., 6., 10., 10., 30., 60., 100.]),
            1e-5,
            1e-6
        ));
    }

    #[test]
    fn reverse_cumsum_scan() {
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  z = f32[] constant(0)\n  ROOT w = f32[4]{0} reduce-window(x, z), window={size=4 pad=0_3}, to_apply=r\n}\n";
        let got = run1(text, &[&t(&[1., 2., 3., 4.])]);
        assert!(allclose(&got, &t(&[10., 9., 7., 4.]), 1e-5, 1e-6));
    }

    #[test]
    fn generic_reduce_window_small_window() {
        // sliding-window max, window 2 stride 1, no padding -> out len 3
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] maximum(a, b)\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  z = f32[] constant(-inf)\n  ROOT w = f32[3]{0} reduce-window(x, z), window={size=2}, to_apply=r\n}\n";
        let got = run1(text, &[&t(&[1., 5., 2., 4.])]);
        assert!(allclose(&got, &t(&[5., 5., 4.]), 1e-6, 1e-7));
    }

    #[test]
    fn tuple_root_returns_all_outputs() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  n = f32[2]{0} negate(x)\n  d = f32[2]{0} add(x, x)\n  ROOT o = (f32[2], f32[2]) tuple(n, d)\n}\n";
        let m = parse_module(text).unwrap();
        let out = evaluate(&m, &[&t(&[1.0, -2.0])]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].data, vec![-1.0, 2.0]);
        assert_eq!(out[1].data, vec![2.0, -4.0]);
    }

    #[test]
    fn wrong_input_arity_and_shape_are_errors() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate(x)\n}\n";
        let m = parse_module(text).unwrap();
        assert!(evaluate(&m, &[]).is_err());
        let wrong = Tensor::from_vec(vec![1.0, 2.0, 3.0]);
        let e = evaluate(&m, &[&wrong]).unwrap_err();
        assert!(e.contains("expects shape"), "{e}");
    }

    #[test]
    fn unsupported_opcode_errors_at_eval() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT y = f32[2]{0} frobnicate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let e = evaluate(&m, &[&t(&[1.0, 2.0])]).unwrap_err();
        assert!(e.contains("frobnicate"), "{e}");
    }

    #[test]
    fn constant_array_literal() {
        let text = "HloModule t\n\nENTRY e {\n  ROOT c = f32[2,2]{1,0} constant({ {1, 2}, {3, 4} })\n}\n";
        let got = run1(text, &[]);
        assert_eq!(got.shape, vec![2, 2]);
        assert_eq!(got.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn iota_walks_the_requested_dimension() {
        let text = "HloModule t\n\nENTRY e {\n  ROOT i = s32[2,3]{1,0} iota(), iota_dimension=1\n}\n";
        let got = run1(text, &[]);
        assert_eq!(got.data, vec![0.0, 1.0, 2.0, 0.0, 1.0, 2.0]);
        assert_eq!(got.dtype, DType::I32);
        let text = "HloModule t\n\nENTRY e {\n  ROOT i = f32[2,3]{1,0} iota(), iota_dimension=0\n}\n";
        let got = run1(text, &[]);
        assert_eq!(got.data, vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn dynamic_slice_clamps_start_indices() {
        // start 2 with size 2 over dim of 3 clamps to 1; start -5 clamps to 0
        let text = "HloModule t\n\nENTRY e {\n  x = f32[3,4]{1,0} parameter(0)\n  i = s32[] constant(2)\n  j = s32[] constant(-5)\n  ROOT d = f32[2,4]{1,0} dynamic-slice(x, i, j), dynamic_slice_sizes={2,4}\n}\n";
        let x = Tensor::new(vec![3, 4], DType::F32, (0..12).map(|v| v as f32).collect());
        let got = run1(text, &[&x]);
        assert_eq!(got.shape, vec![2, 4]);
        assert_eq!(got.data, (4..12).map(|v| v as f32).collect::<Vec<_>>());
    }

    #[test]
    fn while_loop_runs_body_until_condition_flips() {
        // doubles x three times: state (i, x), cond i < 3
        let text = "HloModule t\n\nbody {\n  p = (s32[], f32[2]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  x = f32[2]{0} get-tuple-element(p), index=1\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  x2 = f32[2]{0} add(x, x)\n  ROOT t = (s32[], f32[2]{0}) tuple(i2, x2)\n}\n\ncond {\n  p = (s32[], f32[2]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  n = s32[] constant(3)\n  ROOT c = pred[] compare(i, n), direction=LT\n}\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  z = s32[] constant(0)\n  st = (s32[], f32[2]{0}) tuple(z, x)\n  w = (s32[], f32[2]{0}) while(st), condition=cond, body=body\n  ROOT y = f32[2]{0} get-tuple-element(w), index=1\n}\n";
        let got = run1(text, &[&t(&[1.0, -2.5])]);
        assert_eq!(got.data, vec![8.0, -20.0]);
    }

    #[test]
    fn while_that_never_terminates_errors_out() {
        let text = "HloModule t\n\nbody {\n  p = (s32[]) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  ROOT t = (s32[]) tuple(i)\n}\n\ncond {\n  p = (s32[]) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  ROOT c = pred[] compare(i, i), direction=EQ\n}\n\nENTRY e {\n  z = s32[] constant(0)\n  st = (s32[]) tuple(z)\n  w = (s32[]) while(st), condition=cond, body=body\n  ROOT y = s32[] get-tuple-element(w), index=0\n}\n";
        let m = parse_module(text).unwrap();
        let e = evaluate(&m, &[]).unwrap_err();
        assert!(e.contains("while iterations"), "{e}");
    }

    #[test]
    fn convert_truncates_to_int_and_booleanizes_to_pred() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  i = s32[4]{0} convert(x)\n  p = pred[4]{0} convert(x)\n  ROOT o = (s32[4], pred[4]) tuple(i, p)\n}\n";
        let m = parse_module(text).unwrap();
        let out = evaluate(&m, &[&t(&[2.7, -2.7, 0.0, -0.4])]).unwrap();
        assert_eq!(out[0].data, vec![2.0, -2.0, 0.0, -0.0]);
        assert_eq!(out[0].dtype, DType::I32);
        assert_eq!(out[1].data, vec![1.0, 1.0, 0.0, 1.0]);
        assert_eq!(out[1].dtype, DType::Bool);
    }

    #[test]
    fn output_dtype_follows_the_declared_element_type() {
        let text = "HloModule t\n\nENTRY e {\n  a = s32[2]{0} constant({1, 2})\n  ROOT s = s32[2]{0} add(a, a)\n}\n";
        let got = run1(text, &[]);
        assert_eq!(got.dtype, DType::I32);
        assert_eq!(got.data, vec![2.0, 4.0]);
    }
}
