//! Parser for the HLO text format emitted by `python/compile/aot.py`
//! (`XlaComputation::as_hlo_text`). The grammar is line-oriented:
//!
//! ```text
//! HloModule jit_softmax, entry_computation_layout={...}
//!
//! region_0.4 {                       // subcomputation (reduce combiner)
//!   Arg_0.5 = f32[] parameter(0)
//!   ROOT maximum.7 = f32[] maximum(Arg_0.5, Arg_1.6)
//! }
//!
//! ENTRY main.26 {
//!   Arg_0.1 = f32[8,16]{1,0} parameter(0)
//!   reduce.8 = f32[8]{0} reduce(Arg_0.1, constant.3), dimensions={1}, to_apply=region_0.4
//!   ROOT tuple.25 = (f32[8,16]{1,0}) tuple(divide.24)
//! }
//! ```
//!
//! Operands are resolved to instruction indices during the parse (HLO text
//! is printed in topological order, so a forward reference is malformed
//! input), which both validates the module and makes evaluation cheap.
//! Unknown attributes (`metadata=`, `sharding=`, ...) are skipped; unknown
//! opcodes parse into [`Opcode::Other`] and only fail at evaluation time.

use super::lexer::{lex_line, Token};
use crate::util::tensor::DType;
use std::collections::HashMap;
use std::fmt;

/// Element-type names the parser accepts, exactly as they appear in HLO
/// text. `docs/HLO_SUBSET.md` documents this list and
/// `rust/tests/docs_spec.rs` keeps the two in sync.
pub const SUPPORTED_ELEM_TYPES: &[&str] =
    &["f32", "f64", "f16", "bf16", "pred", "s8", "s32", "s64", "u8", "u32", "u64"];

/// Opcode names the parser maps to a known [`Opcode`] (everything else
/// parses as [`Opcode::Other`] and only fails if evaluated).
/// `docs/HLO_SUBSET.md` documents this list and `rust/tests/docs_spec.rs`
/// keeps the two in sync.
pub const SUPPORTED_OPCODES: &[&str] = &[
    "parameter",
    "constant",
    "add",
    "subtract",
    "multiply",
    "divide",
    "maximum",
    "minimum",
    "power",
    "exponential",
    "log",
    "tanh",
    "sqrt",
    "rsqrt",
    "negate",
    "abs",
    "floor",
    "ceil",
    "sign",
    "logistic",
    "copy",
    "convert",
    "compare",
    "select",
    "reshape",
    "transpose",
    "broadcast",
    "iota",
    "dynamic-slice",
    "reduce",
    "reduce-window",
    "dot",
    "call",
    "while",
    "get-tuple-element",
    "tuple",
];

/// Element type of an HLO array shape. All host data is stored as `f32`;
/// the element type is kept for shape reporting and validation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemType {
    F32,
    F64,
    F16,
    Bf16,
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
}

impl ElemType {
    fn parse(s: &str) -> Option<ElemType> {
        match s {
            "f32" => Some(ElemType::F32),
            "f64" => Some(ElemType::F64),
            "f16" => Some(ElemType::F16),
            "bf16" => Some(ElemType::Bf16),
            "pred" => Some(ElemType::Pred),
            "s8" => Some(ElemType::S8),
            "s32" => Some(ElemType::S32),
            "s64" => Some(ElemType::S64),
            "u8" => Some(ElemType::U8),
            "u32" => Some(ElemType::U32),
            "u64" => Some(ElemType::U64),
            _ => None,
        }
    }

    /// The HLO text spelling of this element type.
    pub fn name(self) -> &'static str {
        match self {
            ElemType::F32 => "f32",
            ElemType::F64 => "f64",
            ElemType::F16 => "f16",
            ElemType::Bf16 => "bf16",
            ElemType::Pred => "pred",
            ElemType::S8 => "s8",
            ElemType::S32 => "s32",
            ElemType::S64 => "s64",
            ElemType::U8 => "u8",
            ElemType::U32 => "u32",
            ElemType::U64 => "u64",
        }
    }

    /// The host [`DType`] values of this element type are tagged with.
    /// Host storage is always `f32`; the logical dtype rides along so
    /// oracle outputs report `s32[64]` as an `I32` tensor, not `F32`.
    /// Widths collapse where the host has no finer tag: `f64` reports as
    /// `F32`, `bf16` as `F16`, and unsigned types as their signed
    /// siblings (documented in `docs/HLO_SUBSET.md`).
    pub fn dtype(self) -> DType {
        match self {
            ElemType::F32 | ElemType::F64 => DType::F32,
            ElemType::F16 | ElemType::Bf16 => DType::F16,
            ElemType::Pred => DType::Bool,
            ElemType::S8 | ElemType::U8 => DType::I8,
            ElemType::S32 | ElemType::U32 => DType::I32,
            ElemType::S64 | ElemType::U64 => DType::I64,
        }
    }

    /// Is this one of the signed/unsigned integer element types?
    pub fn is_int(self) -> bool {
        matches!(
            self,
            ElemType::S8
                | ElemType::S32
                | ElemType::S64
                | ElemType::U8
                | ElemType::U32
                | ElemType::U64
        )
    }
}

/// A dense array shape (`f32[512,2048]`). Layout annotations are ignored.
#[derive(Clone, Debug, PartialEq)]
pub struct Shape {
    pub elem: ElemType,
    pub dims: Vec<usize>,
}

impl Shape {
    /// Total element count (product of `dims`; 1 for scalars).
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.elem.name(), dims.join(","))
    }
}

/// Result shape of an instruction: a plain array or (for `tuple`) a tuple.
#[derive(Clone, Debug, PartialEq)]
pub enum InstrShape {
    Array(Shape),
    Tuple(Vec<Shape>),
}

impl InstrShape {
    /// The array shape, or an error message for tuple-shaped results.
    pub fn array(&self) -> Result<&Shape, String> {
        match self {
            InstrShape::Array(s) => Ok(s),
            InstrShape::Tuple(_) => Err("expected array shape, found tuple".to_string()),
        }
    }
}

/// Comparison direction of a `compare` instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Ge,
    Gt,
    Le,
    Lt,
}

impl CmpDir {
    fn parse(s: &str) -> Option<CmpDir> {
        match s {
            "EQ" => Some(CmpDir::Eq),
            "NE" => Some(CmpDir::Ne),
            "GE" => Some(CmpDir::Ge),
            "GT" => Some(CmpDir::Gt),
            "LE" => Some(CmpDir::Le),
            "LT" => Some(CmpDir::Lt),
            _ => None,
        }
    }
}

/// `window={size=.. stride=.. pad=..}` of a `reduce-window` instruction.
/// Missing fields default to stride 1 / pad 0 per dimension.
#[derive(Clone, Debug, PartialEq)]
pub struct Window {
    pub size: Vec<usize>,
    pub stride: Vec<usize>,
    /// (low, high) padding per dimension.
    pub pad: Vec<(usize, usize)>,
}

/// Instruction opcodes the interpreter knows about. Anything else parses
/// into `Other` and produces an evaluation error only if reached.
#[derive(Clone, Debug, PartialEq)]
pub enum Opcode {
    Parameter,
    Constant,
    Add,
    Subtract,
    Multiply,
    Divide,
    Maximum,
    Minimum,
    Power,
    Exponential,
    Log,
    Tanh,
    Sqrt,
    Rsqrt,
    Negate,
    Abs,
    Floor,
    Ceil,
    Sign,
    Logistic,
    Copy,
    Convert,
    Compare,
    Select,
    Reshape,
    Transpose,
    Broadcast,
    Iota,
    DynamicSlice,
    Reduce,
    ReduceWindow,
    Dot,
    Call,
    While,
    GetTupleElement,
    Tuple,
    Other(String),
}

impl Opcode {
    fn parse(s: &str) -> Opcode {
        match s {
            "parameter" => Opcode::Parameter,
            "constant" => Opcode::Constant,
            "add" => Opcode::Add,
            "subtract" => Opcode::Subtract,
            "multiply" => Opcode::Multiply,
            "divide" => Opcode::Divide,
            "maximum" => Opcode::Maximum,
            "minimum" => Opcode::Minimum,
            "power" => Opcode::Power,
            "exponential" => Opcode::Exponential,
            "log" => Opcode::Log,
            "tanh" => Opcode::Tanh,
            "sqrt" => Opcode::Sqrt,
            "rsqrt" => Opcode::Rsqrt,
            "negate" => Opcode::Negate,
            "abs" => Opcode::Abs,
            "floor" => Opcode::Floor,
            "ceil" => Opcode::Ceil,
            "sign" => Opcode::Sign,
            "logistic" => Opcode::Logistic,
            "copy" => Opcode::Copy,
            "convert" => Opcode::Convert,
            "compare" => Opcode::Compare,
            "select" => Opcode::Select,
            "reshape" => Opcode::Reshape,
            "transpose" => Opcode::Transpose,
            "broadcast" => Opcode::Broadcast,
            "iota" => Opcode::Iota,
            "dynamic-slice" => Opcode::DynamicSlice,
            "reduce" => Opcode::Reduce,
            "reduce-window" => Opcode::ReduceWindow,
            "dot" => Opcode::Dot,
            "call" => Opcode::Call,
            "while" => Opcode::While,
            "get-tuple-element" => Opcode::GetTupleElement,
            "tuple" => Opcode::Tuple,
            other => Opcode::Other(other.to_string()),
        }
    }
}

/// One parsed instruction.
#[derive(Clone, Debug)]
pub struct Instr {
    pub name: String,
    pub shape: InstrShape,
    pub opcode: Opcode,
    /// Operand indices into the owning computation's `instrs`.
    pub operands: Vec<usize>,
    pub is_root: bool,
    /// `parameter(N)` index.
    pub param_index: Option<usize>,
    /// Flattened `constant(...)` payload (row-major).
    pub literal: Option<Vec<f32>>,
    /// `dimensions={...}` (broadcast / reduce / transpose).
    pub dimensions: Option<Vec<usize>>,
    /// `to_apply=name` (reduce / reduce-window / call).
    pub to_apply: Option<String>,
    /// `direction=GE` (compare).
    pub direction: Option<CmpDir>,
    pub lhs_contract: Vec<usize>,
    pub rhs_contract: Vec<usize>,
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub window: Option<Window>,
    /// `iota_dimension=N` (iota).
    pub iota_dim: Option<usize>,
    /// `dynamic_slice_sizes={...}` (dynamic-slice).
    pub slice_sizes: Vec<usize>,
    /// `condition=name` (while).
    pub condition: Option<String>,
    /// `body=name` (while).
    pub body: Option<String>,
    /// `index=N` (get-tuple-element).
    pub tuple_index: Option<usize>,
}

/// A named computation: entry or subcomputation (combiner, called fn).
#[derive(Clone, Debug)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    /// Instruction indices in parameter order (0, 1, 2, ...).
    pub params: Vec<usize>,
    /// Index of the ROOT instruction.
    pub root: usize,
}

/// A parsed HLO module.
#[derive(Clone, Debug)]
pub struct Module {
    pub name: String,
    pub computations: Vec<Computation>,
    /// Index of the ENTRY computation.
    pub entry: usize,
    by_name: HashMap<String, usize>,
}

impl Module {
    /// The ENTRY computation (the one `evaluate`/plan compilation run).
    pub fn entry_computation(&self) -> &Computation {
        &self.computations[self.entry]
    }

    /// Index of computation `name` (reduce combiners, call targets,
    /// while conditions/bodies), if it exists.
    pub fn computation_index(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }
}

/// A parse failure with its 1-based source line.
#[derive(Clone, Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError { line, msg: msg.into() })
}

// ------------------------------------------------------------------ cursor

struct Cursor {
    toks: Vec<Token>,
    pos: usize,
    line: usize,
}

impl Cursor {
    fn new(toks: Vec<Token>, line: usize) -> Cursor {
        Cursor { toks, pos: 0, line }
    }

    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn peek_punct(&self, c: char) -> bool {
        matches!(self.peek(), Some(Token::Punct(p)) if *p == c)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.toks.get(self.pos).cloned();
        self.pos += 1;
        t
    }

    fn done(&self) -> bool {
        self.pos >= self.toks.len()
    }

    fn expect_punct(&mut self, c: char) -> Result<(), ParseError> {
        match self.next() {
            Some(Token::Punct(p)) if p == c => Ok(()),
            Some(t) => err(self.line, format!("expected '{c}', found {}", t.describe())),
            None => err(self.line, format!("expected '{c}', found end of line")),
        }
    }

    fn word(&mut self) -> Result<String, ParseError> {
        match self.next() {
            Some(Token::Word(w)) => Ok(w),
            Some(t) => err(self.line, format!("expected identifier, found {}", t.describe())),
            None => err(self.line, "expected identifier, found end of line"),
        }
    }

    fn usize_word(&mut self) -> Result<usize, ParseError> {
        let line = self.line;
        let w = self.word()?;
        w.parse::<usize>()
            .map_err(|_| ParseError { line, msg: format!("expected integer, found '{w}'") })
    }

    /// `{1,2,3}` (possibly empty).
    fn usize_list(&mut self) -> Result<Vec<usize>, ParseError> {
        self.expect_punct('{')?;
        let mut out = Vec::new();
        while !self.peek_punct('}') {
            out.push(self.usize_word()?);
            if self.peek_punct(',') {
                self.next();
            }
        }
        self.expect_punct('}')?;
        Ok(out)
    }

    /// Skip a balanced `{...}` group (layouts, metadata, sharding, ...).
    fn skip_braced(&mut self) -> Result<(), ParseError> {
        self.expect_punct('{')?;
        let mut depth = 1usize;
        while depth > 0 {
            match self.next() {
                Some(Token::Punct('{')) => depth += 1,
                Some(Token::Punct('}')) => depth -= 1,
                Some(_) => {}
                None => return err(self.line, "unterminated '{' group"),
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------------- sub-parsers

fn parse_shape(c: &mut Cursor) -> Result<Shape, ParseError> {
    let line = c.line;
    let ty = c.word()?;
    let elem = match ElemType::parse(&ty) {
        Some(e) => e,
        None => return err(line, format!("unsupported element type '{ty}'")),
    };
    let mut dims = Vec::new();
    if c.peek_punct('[') {
        c.next();
        while !c.peek_punct(']') {
            dims.push(c.usize_word()?);
            if c.peek_punct(',') {
                c.next();
            }
        }
        c.expect_punct(']')?;
    }
    // optional layout annotation, e.g. {1,0} — skipped
    if c.peek_punct('{') {
        c.skip_braced()?;
    }
    Ok(Shape { elem, dims })
}

fn parse_scalar(line: usize, w: &str) -> Result<f32, ParseError> {
    match w {
        "inf" | "+inf" => Ok(f32::INFINITY),
        "-inf" => Ok(f32::NEG_INFINITY),
        "nan" | "-nan" => Ok(f32::NAN),
        "true" => Ok(1.0),
        "false" => Ok(0.0),
        _ => w
            .parse::<f32>()
            .map_err(|_| ParseError { line, msg: format!("invalid literal value '{w}'") }),
    }
}

/// `constant(...)` payload: a scalar or nested `{...}` rows; flattened
/// row-major, which matches the printer's element order.
fn parse_literal(c: &mut Cursor, shape: &Shape) -> Result<Vec<f32>, ParseError> {
    let mut vals = Vec::new();
    let mut depth = 0usize;
    loop {
        match c.peek() {
            None => return err(c.line, "unterminated constant literal"),
            Some(Token::Punct(')')) if depth == 0 => break,
            Some(Token::Punct('{')) => {
                depth += 1;
                c.next();
            }
            Some(Token::Punct('}')) => {
                if depth == 0 {
                    return err(c.line, "unbalanced '}' in constant literal");
                }
                depth -= 1;
                c.next();
            }
            Some(Token::Punct(',')) => {
                c.next();
            }
            Some(Token::Word(_)) => {
                let line = c.line;
                let w = c.word()?;
                vals.push(parse_scalar(line, &w)?);
            }
            Some(t) => {
                return err(c.line, format!("unexpected {} in constant literal", t.describe()))
            }
        }
    }
    if vals.len() != shape.numel() {
        return err(
            c.line,
            format!("constant has {} elements but shape {shape} wants {}", vals.len(), shape.numel()),
        );
    }
    Ok(vals)
}

fn parse_dim_spec(line: usize, w: &str) -> Result<Vec<usize>, ParseError> {
    w.split('x')
        .map(|p| {
            p.parse::<usize>()
                .map_err(|_| ParseError { line, msg: format!("invalid window dimension '{p}'") })
        })
        .collect()
}

/// `window={size=1x2048 stride=1x1 pad=0_0x2047_0}`.
fn parse_window(c: &mut Cursor) -> Result<Window, ParseError> {
    c.expect_punct('{')?;
    let mut size: Option<Vec<usize>> = None;
    let mut stride: Option<Vec<usize>> = None;
    let mut pad: Option<Vec<(usize, usize)>> = None;
    while !c.peek_punct('}') {
        let line = c.line;
        let key = c.word()?;
        c.expect_punct('=')?;
        let val = c.word()?;
        match key.as_str() {
            "size" => size = Some(parse_dim_spec(line, &val)?),
            "stride" => stride = Some(parse_dim_spec(line, &val)?),
            "pad" => {
                let mut pairs = Vec::new();
                for part in val.split('x') {
                    let mut it = part.split('_');
                    let lo = it.next().unwrap_or("");
                    let hi = it.next().unwrap_or("0");
                    let parse = |s: &str| {
                        s.parse::<usize>().map_err(|_| ParseError {
                            line,
                            msg: format!("invalid window pad '{part}'"),
                        })
                    };
                    pairs.push((parse(lo)?, parse(hi)?));
                }
                pad = Some(pairs);
            }
            _ => {} // lhs_dilate etc.: not produced by our build path
        }
    }
    c.expect_punct('}')?;
    let size = match size {
        Some(s) => s,
        None => return err(c.line, "window attribute has no size"),
    };
    let rank = size.len();
    Ok(Window {
        stride: stride.unwrap_or_else(|| vec![1; rank]),
        pad: pad.unwrap_or_else(|| vec![(0, 0); rank]),
        size,
    })
}

fn parse_instr(
    mut c: Cursor,
    by_name: &HashMap<String, usize>,
) -> Result<Instr, ParseError> {
    let mut name = c.word()?;
    let mut is_root = false;
    if name == "ROOT" {
        is_root = true;
        name = c.word()?;
    }
    c.expect_punct('=')?;
    let shape = if c.peek_punct('(') {
        c.next();
        let mut shapes = Vec::new();
        while !c.peek_punct(')') {
            shapes.push(parse_shape(&mut c)?);
            if c.peek_punct(',') {
                c.next();
            }
        }
        c.expect_punct(')')?;
        InstrShape::Tuple(shapes)
    } else {
        InstrShape::Array(parse_shape(&mut c)?)
    };
    let op_word = c.word()?;
    let opcode = Opcode::parse(&op_word);
    let mut ins = Instr {
        name,
        shape,
        opcode,
        operands: Vec::new(),
        is_root,
        param_index: None,
        literal: None,
        dimensions: None,
        to_apply: None,
        direction: None,
        lhs_contract: Vec::new(),
        rhs_contract: Vec::new(),
        lhs_batch: Vec::new(),
        rhs_batch: Vec::new(),
        window: None,
        iota_dim: None,
        slice_sizes: Vec::new(),
        condition: None,
        body: None,
        tuple_index: None,
    };
    c.expect_punct('(')?;
    match ins.opcode {
        Opcode::Constant => {
            let shape = match &ins.shape {
                InstrShape::Array(s) => s.clone(),
                InstrShape::Tuple(_) => return err(c.line, "tuple-shaped constant"),
            };
            ins.literal = Some(parse_literal(&mut c, &shape)?);
            c.expect_punct(')')?;
        }
        Opcode::Parameter => {
            ins.param_index = Some(c.usize_word()?);
            c.expect_punct(')')?;
        }
        _ => {
            while !c.peek_punct(')') {
                let line = c.line;
                let op_name = c.word()?;
                match by_name.get(&op_name) {
                    Some(&idx) => ins.operands.push(idx),
                    None => {
                        return err(
                            line,
                            format!("operand '{op_name}' of '{}' is not defined above", ins.name),
                        )
                    }
                }
                if c.peek_punct(',') {
                    c.next();
                }
            }
            c.expect_punct(')')?;
        }
    }
    // trailing attributes: `, key=value` pairs
    while !c.done() {
        match c.next() {
            Some(Token::Punct(',')) => continue,
            Some(Token::Word(key)) => {
                c.expect_punct('=')?;
                match key.as_str() {
                    "dimensions" => ins.dimensions = Some(c.usize_list()?),
                    "to_apply" => ins.to_apply = Some(c.word()?),
                    "direction" => {
                        let line = c.line;
                        let w = c.word()?;
                        ins.direction = match CmpDir::parse(&w) {
                            Some(d) => Some(d),
                            None => return err(line, format!("unknown compare direction '{w}'")),
                        };
                    }
                    "lhs_contracting_dims" => ins.lhs_contract = c.usize_list()?,
                    "rhs_contracting_dims" => ins.rhs_contract = c.usize_list()?,
                    "lhs_batch_dims" => ins.lhs_batch = c.usize_list()?,
                    "rhs_batch_dims" => ins.rhs_batch = c.usize_list()?,
                    "window" => ins.window = Some(parse_window(&mut c)?),
                    "iota_dimension" => ins.iota_dim = Some(c.usize_word()?),
                    "dynamic_slice_sizes" => ins.slice_sizes = c.usize_list()?,
                    "condition" => ins.condition = Some(c.word()?),
                    "body" => ins.body = Some(c.word()?),
                    "index" => ins.tuple_index = Some(c.usize_word()?),
                    _ => {
                        // metadata=, sharding=, frontend_attributes=, ...
                        if c.peek_punct('{') {
                            c.skip_braced()?;
                        } else {
                            c.next();
                        }
                    }
                }
            }
            Some(t) => return err(c.line, format!("unexpected {} after operand list", t.describe())),
            None => break,
        }
    }
    Ok(ins)
}

// ------------------------------------------------------------ module parse

struct CompBuilder {
    name: String,
    is_entry: bool,
    instrs: Vec<Instr>,
    by_name: HashMap<String, usize>,
    start_line: usize,
}

impl CompBuilder {
    fn finish(self, end_line: usize) -> Result<(Computation, bool), ParseError> {
        let mut params: Vec<(usize, usize)> = Vec::new();
        let mut root = None;
        for (idx, ins) in self.instrs.iter().enumerate() {
            if let Some(pi) = ins.param_index {
                params.push((pi, idx));
            }
            if ins.is_root {
                if root.is_some() {
                    return err(end_line, format!("computation '{}' has two ROOTs", self.name));
                }
                root = Some(idx);
            }
        }
        let root = match root {
            Some(r) => r,
            None => {
                return err(end_line, format!("computation '{}' has no ROOT instruction", self.name))
            }
        };
        params.sort();
        for (want, (got, _)) in params.iter().enumerate() {
            if *got != want {
                return err(
                    self.start_line,
                    format!("computation '{}' has non-contiguous parameter indices", self.name),
                );
            }
        }
        Ok((
            Computation {
                name: self.name,
                instrs: self.instrs,
                params: params.into_iter().map(|(_, idx)| idx).collect(),
                root,
            },
            self.is_entry,
        ))
    }
}

/// Parse a full HLO text module.
pub fn parse_module(text: &str) -> Result<Module, ParseError> {
    let mut module_name: Option<String> = None;
    let mut computations: Vec<Computation> = Vec::new();
    let mut by_name: HashMap<String, usize> = HashMap::new();
    let mut entry: Option<usize> = None;
    let mut current: Option<CompBuilder> = None;

    for (lineno, raw) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            if module_name.is_some() {
                return err(lineno, "duplicate HloModule header");
            }
            let name = rest.split_whitespace().next().unwrap_or("").trim_end_matches(',');
            if name.is_empty() {
                return err(lineno, "HloModule header has no name");
            }
            module_name = Some(name.to_string());
            continue;
        }
        if module_name.is_none() {
            return err(lineno, "content before HloModule header");
        }
        if line == "}" {
            match current.take() {
                Some(builder) => {
                    let (comp, is_entry) = builder.finish(lineno)?;
                    if by_name.contains_key(&comp.name) {
                        return err(lineno, format!("duplicate computation '{}'", comp.name));
                    }
                    by_name.insert(comp.name.clone(), computations.len());
                    if is_entry {
                        entry = Some(computations.len());
                    }
                    computations.push(comp);
                }
                None => return err(lineno, "'}' outside a computation"),
            }
            continue;
        }
        if line.ends_with('{') && !line.contains('=') {
            if current.is_some() {
                return err(lineno, "computation header inside a computation");
            }
            let header = line[..line.len() - 1].trim();
            let (is_entry, header) = match header.strip_prefix("ENTRY") {
                Some(rest) => (true, rest.trim()),
                None => (false, header),
            };
            // header may carry a `(params) -> result` signature; the name
            // is the first word either way
            let name = header.split(|ch: char| ch.is_whitespace() || ch == '(').next().unwrap_or("");
            let name = name.strip_prefix('%').unwrap_or(name);
            if name.is_empty() {
                return err(lineno, "computation header has no name");
            }
            current = Some(CompBuilder {
                name: name.to_string(),
                is_entry,
                instrs: Vec::new(),
                by_name: HashMap::new(),
                start_line: lineno,
            });
            continue;
        }
        let builder = match current.as_mut() {
            Some(b) => b,
            None => return err(lineno, format!("instruction outside a computation: '{line}'")),
        };
        let toks = match lex_line(line) {
            Ok(t) => t,
            Err(msg) => return err(lineno, msg),
        };
        let ins = parse_instr(Cursor::new(toks, lineno), &builder.by_name)?;
        if builder.by_name.contains_key(&ins.name) {
            return err(lineno, format!("duplicate instruction name '{}'", ins.name));
        }
        builder.by_name.insert(ins.name.clone(), builder.instrs.len());
        builder.instrs.push(ins);
    }

    if let Some(b) = current {
        return err(b.start_line, format!("computation '{}' is never closed", b.name));
    }
    let name = match module_name {
        Some(n) => n,
        None => return err(1, "no HloModule header found"),
    };
    let entry = match entry {
        Some(e) => e,
        None => return err(1, "module has no ENTRY computation"),
    };
    // every referenced computation (to_apply / while condition+body) must
    // resolve
    for comp in &computations {
        for ins in &comp.instrs {
            for target in [&ins.to_apply, &ins.condition, &ins.body].into_iter().flatten() {
                if !by_name.contains_key(target) {
                    return err(
                        1,
                        format!("'{}' applies unknown computation '{target}'", ins.name),
                    );
                }
            }
        }
    }
    Ok(Module { name, computations, entry, by_name })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SOFTMAX_8X16: &str = r#"HloModule jit_softmax, entry_computation_layout={(f32[8,16]{1,0})->(f32[8,16]{1,0})}

region_0.4 {
  Arg_0.5 = f32[] parameter(0)
  Arg_1.6 = f32[] parameter(1)
  ROOT maximum.7 = f32[] maximum(Arg_0.5, Arg_1.6)
}

region_1.15 {
  Arg_0.16 = f32[] parameter(0)
  Arg_1.17 = f32[] parameter(1)
  ROOT add.18 = f32[] add(Arg_0.16, Arg_1.17)
}

ENTRY main.26 {
  Arg_0.1 = f32[8,16]{1,0} parameter(0)
  constant.3 = f32[] constant(-inf)
  reduce.8 = f32[8]{0} reduce(Arg_0.1, constant.3), dimensions={1}, to_apply=region_0.4
  reshape.9 = f32[8,1]{1,0} reshape(reduce.8)
  reshape.11 = f32[8]{0} reshape(reshape.9)
  broadcast.12 = f32[8,16]{1,0} broadcast(reshape.11), dimensions={0}
  subtract.13 = f32[8,16]{1,0} subtract(Arg_0.1, broadcast.12)
  exponential.14 = f32[8,16]{1,0} exponential(subtract.13)
  constant.2 = f32[] constant(0)
  reduce.19 = f32[8]{0} reduce(exponential.14, constant.2), dimensions={1}, to_apply=region_1.15
  reshape.22 = f32[8]{0} reshape(reduce.19)
  broadcast.23 = f32[8,16]{1,0} broadcast(reshape.22), dimensions={0}
  divide.24 = f32[8,16]{1,0} divide(exponential.14, broadcast.23)
  ROOT tuple.25 = (f32[8,16]{1,0}) tuple(divide.24)
}
"#;

    #[test]
    fn parses_softmax_module() {
        let m = parse_module(SOFTMAX_8X16).unwrap();
        assert_eq!(m.name, "jit_softmax");
        assert_eq!(m.computations.len(), 3);
        let entry = m.entry_computation();
        assert_eq!(entry.name, "main.26");
        assert_eq!(entry.params.len(), 1);
        let root = &entry.instrs[entry.root];
        assert_eq!(root.opcode, Opcode::Tuple);
        match &root.shape {
            InstrShape::Tuple(shapes) => {
                assert_eq!(shapes.len(), 1);
                assert_eq!(shapes[0].dims, vec![8, 16]);
            }
            other => panic!("expected tuple root shape, got {other:?}"),
        }
        // reduce points at the maximum combiner
        let reduce = entry.instrs.iter().find(|i| i.name == "reduce.8").unwrap();
        assert_eq!(reduce.dimensions, Some(vec![1]));
        assert_eq!(reduce.to_apply.as_deref(), Some("region_0.4"));
        assert!(m.computation_index("region_0.4").is_some());
    }

    #[test]
    fn constant_forms() {
        let text = "HloModule t\n\nENTRY e {\n  c1 = f32[] constant(-inf)\n  c2 = f32[2]{0} constant({1.5, -2})\n  c3 = f32[1,1]{1,0} constant({ {4194304} })\n  ROOT t.1 = (f32[], f32[2], f32[1,1]) tuple(c1, c2, c3)\n}\n";
        let m = parse_module(text).unwrap();
        let e = m.entry_computation();
        assert_eq!(e.instrs[0].literal, Some(vec![f32::NEG_INFINITY]));
        assert_eq!(e.instrs[1].literal, Some(vec![1.5, -2.0]));
        assert_eq!(e.instrs[2].literal, Some(vec![4194304.0]));
    }

    #[test]
    fn window_attribute_parses() {
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[512,2048]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT w.1 = f32[512,2048]{1,0} reduce-window(x, z), window={size=1x2048 pad=0_0x2047_0}, to_apply=r\n}\n";
        let m = parse_module(text).unwrap();
        let e = m.entry_computation();
        let w = e.instrs[e.root].window.as_ref().unwrap();
        assert_eq!(w.size, vec![1, 2048]);
        assert_eq!(w.stride, vec![1, 1]);
        assert_eq!(w.pad, vec![(0, 0), (2047, 0)]);
    }

    #[test]
    fn forward_reference_is_rejected_with_line() {
        let text = "HloModule t\n\nENTRY e {\n  y = f32[] negate(x)\n  ROOT x = f32[] parameter(0)\n}\n";
        let e = parse_module(text).unwrap_err();
        assert_eq!(e.line, 4);
        assert!(e.msg.contains("not defined above"), "{}", e.msg);
    }

    #[test]
    fn missing_root_is_rejected() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[] parameter(0)\n}\n";
        let e = parse_module(text).unwrap_err();
        assert!(e.msg.contains("no ROOT"), "{}", e.msg);
    }

    #[test]
    fn missing_entry_is_rejected() {
        let text = "HloModule t\n\nr {\n  ROOT x = f32[] parameter(0)\n}\n";
        let e = parse_module(text).unwrap_err();
        assert!(e.msg.contains("ENTRY"), "{}", e.msg);
    }

    #[test]
    fn garbage_line_is_rejected_with_line_number() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[] parameter(0)\n  what even is this\n  ROOT y = f32[] negate(x)\n}\n";
        let e = parse_module(text).unwrap_err();
        assert_eq!(e.line, 5);
    }

    #[test]
    fn unknown_opcode_parses_as_other() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  ROOT y = f32[4]{0} frobnicate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let e = m.entry_computation();
        assert_eq!(e.instrs[e.root].opcode, Opcode::Other("frobnicate".to_string()));
    }

    #[test]
    fn unknown_attributes_are_skipped() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  ROOT y = f32[4]{0} negate(x), metadata={op_type=\"neg\" op_name=\"jit(f)/neg\" source_file=\"a,b.py\" source_line=3}, backend_config=\"cfg\"\n}\n";
        let m = parse_module(text).unwrap();
        assert_eq!(m.entry_computation().instrs.len(), 2);
    }

    #[test]
    fn iota_and_dynamic_slice_attributes_parse() {
        let text = "HloModule t\n\nENTRY e {\n  i = s32[4,3]{1,0} iota(), iota_dimension=1\n  x = f32[4,3]{1,0} parameter(0)\n  z = s32[] constant(0)\n  ROOT d = f32[2,3]{1,0} dynamic-slice(x, z, z), dynamic_slice_sizes={2,3}\n}\n";
        let m = parse_module(text).unwrap();
        let e = m.entry_computation();
        let iota = &e.instrs[0];
        assert_eq!(iota.opcode, Opcode::Iota);
        assert_eq!(iota.iota_dim, Some(1));
        assert!(iota.operands.is_empty());
        let ds = &e.instrs[e.root];
        assert_eq!(ds.opcode, Opcode::DynamicSlice);
        assert_eq!(ds.slice_sizes, vec![2, 3]);
        assert_eq!(ds.operands.len(), 3);
    }

    #[test]
    fn while_and_get_tuple_element_parse() {
        let text = "HloModule t\n\nbody {\n  p = (s32[], f32[2]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  x = f32[2]{0} get-tuple-element(p), index=1\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  x2 = f32[2]{0} add(x, x)\n  ROOT t = (s32[], f32[2]{0}) tuple(i2, x2)\n}\n\ncond {\n  p = (s32[], f32[2]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  n = s32[] constant(3)\n  ROOT c = pred[] compare(i, n), direction=LT\n}\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  z = s32[] constant(0)\n  st = (s32[], f32[2]{0}) tuple(z, x)\n  w = (s32[], f32[2]{0}) while(st), condition=cond, body=body\n  ROOT y = f32[2]{0} get-tuple-element(w), index=1\n}\n";
        let m = parse_module(text).unwrap();
        let e = m.entry_computation();
        let w = e.instrs.iter().find(|i| i.name == "w").unwrap();
        assert_eq!(w.opcode, Opcode::While);
        assert_eq!(w.condition.as_deref(), Some("cond"));
        assert_eq!(w.body.as_deref(), Some("body"));
        match &w.shape {
            InstrShape::Tuple(shapes) => assert_eq!(shapes.len(), 2),
            other => panic!("expected tuple while shape, got {other:?}"),
        }
        let gte = &e.instrs[e.root];
        assert_eq!(gte.opcode, Opcode::GetTupleElement);
        assert_eq!(gte.tuple_index, Some(1));
        // body's tuple-shaped parameter parses with both element shapes
        let body = &m.computations[m.computation_index("body").unwrap()];
        match &body.instrs[body.params[0]].shape {
            InstrShape::Tuple(shapes) => {
                assert_eq!(shapes[0].elem, ElemType::S32);
                assert_eq!(shapes[1].dims, vec![2]);
            }
            other => panic!("expected tuple parameter shape, got {other:?}"),
        }
    }

    #[test]
    fn while_with_unknown_body_is_rejected() {
        let text = "HloModule t\n\nENTRY e {\n  x = (f32[2]{0}) parameter(0)\n  ROOT w = (f32[2]{0}) while(x), condition=nope, body=nada\n}\n";
        let e = parse_module(text).unwrap_err();
        assert!(e.msg.contains("unknown computation"), "{}", e.msg);
    }

    #[test]
    fn supported_opcode_list_matches_the_parser() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for name in SUPPORTED_OPCODES {
            let op = Opcode::parse(name);
            assert!(
                !matches!(op, Opcode::Other(_)),
                "'{name}' is listed as supported but parses to Other"
            );
            assert!(seen.insert(format!("{op:?}")), "'{name}' parses to a duplicate opcode");
        }
        for name in SUPPORTED_ELEM_TYPES {
            assert!(ElemType::parse(name).is_some(), "'{name}' listed but not parsed");
            assert_eq!(ElemType::parse(name).unwrap().name(), *name);
        }
        assert!(ElemType::parse("c64").is_none());
    }

    #[test]
    fn real_artifact_round_trips_through_parser() {
        // checked-in fixture (repo-root artifacts/, relative to this crate)
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts/softmax.hlo.txt");
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return, // fixture tree not present (e.g. crate vendored alone)
        };
        let m = parse_module(&text).unwrap();
        assert_eq!(m.entry_computation().params.len(), 1);
        let shape = m.entry_computation().instrs[m.entry_computation().params[0]]
            .shape
            .array()
            .unwrap()
            .clone();
        assert_eq!(shape.dims, vec![512, 2048]);
    }
}
