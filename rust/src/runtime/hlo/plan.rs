//! Compile-once / execute-many plans for HLO modules.
//!
//! [`ExecutablePlan::compile`] turns a parsed [`Module`] into a flat step
//! program, doing all per-module work up front so repeated executions (the
//! oracle runs once per suite task per seed) pay none of it:
//!
//! * **call inlining** — `call` instructions are flattened into the caller,
//!   so execution is a single linear sweep (the parser's topological order
//!   is preserved);
//! * **elementwise fusion** — chains of single-use elementwise instructions
//!   (arithmetic, compare/select, reshape/copy/convert, scalar broadcasts)
//!   collapse into one fused-step expression evaluated in cache-sized
//!   chunks: intermediates live in L1-resident scratch instead of
//!   full-tensor allocations;
//! * **combiner resolution** — `reduce`/`reduce-window` combiner
//!   computations resolve to a static combiner enum at compile time (exotic
//!   combiners compile to a scalar expression; nothing is re-interpreted
//!   per element);
//! * **buffer arena** — last-use liveness analysis assigns instruction
//!   outputs to recycled arena slots, so executing a module allocates a
//!   handful of buffers instead of one per instruction. A step's output
//!   slot is acquired *before* its operands' slots are released, so an
//!   output can never alias a live operand;
//! * **tuple flattening** — `tuple`, `get-tuple-element`, and
//!   tuple-returning `call`s resolve to flat node ids at compile time
//!   (tuples never materialize); `iota` folds into a compile-time
//!   constant; `while` compiles its condition and body into *nested*
//!   plans executed by a dedicated step whose scratches persist in
//!   [`PlanScratch`] across runs.
//!
//! Numerics are bit-identical to the [`super::eval`] tree-walker: the same
//! scalar operations in the same accumulation widths and orders. The
//! tree-walker intentionally keeps its own hand-rolled loops (an
//! *independent* baseline rather than a consumer of
//! [`crate::util::kernels`]), so the invariant is enforced by
//! `rust/tests/plan_differential.rs` — randomized programs plus every
//! checked-in fixture, compared bit-for-bit — not by code sharing. The
//! tree-walker also serves as the fallback for modules outside the plan
//! compiler's scope.

use super::parser::{CmpDir, Instr, InstrShape, Module, Opcode};
use super::MAX_WHILE_ITERS;
use crate::util::kernels::{self, BinOp, CmpOp, UnaryOp};
use crate::util::tensor::{DType, Tensor};
use std::sync::Mutex;

/// Plan compilation knobs (the hotpath bench flips the arena off to
/// measure what buffer recycling is worth, and flips `parallel` on to
/// measure the wave schedule).
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Recycle dead output buffers through a free list (the arena). When
    /// false every step gets a private slot.
    pub reuse_buffers: bool,
    /// Execute independent steps concurrently on the worker pool, wave by
    /// wave over the step dependency DAG. Bit-identical to serial
    /// execution — the schedule only reorders steps that share no arena
    /// hazard — and a no-op on a one-thread pool.
    pub parallel: bool,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions { reuse_buffers: true, parallel: false }
    }
}

/// Where a step input comes from. During compilation `Buf` holds a flat
/// node id; [`ExecutablePlan::compile_with`] rewrites it to an arena slot
/// id before the plan is returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Src {
    /// Entry parameter `i` (borrowed from the caller).
    Input(usize),
    /// Compile-time constant tensor.
    Const(usize),
    /// Arena slot (node id pre-lowering).
    Buf(usize),
}

/// A fused elementwise expression. Leaves are materialized sources; every
/// interior op maps flat element `i` of its children to element `i` of the
/// result, so the whole tree evaluates in one chunked pass.
#[derive(Clone, Debug)]
enum FExpr {
    Leaf(Src),
    /// Broadcast of a compile-time scalar.
    Splat(f32),
    /// Broadcast of a runtime scalar (element 0 of a materialized source).
    SplatLeaf(Src),
    Un(UnaryOp, Box<FExpr>),
    Bin(BinOp, Box<FExpr>, Box<FExpr>),
    Cmp(CmpOp, Box<FExpr>, Box<FExpr>),
    /// select(cond, on_true, on_false).
    Sel(Box<FExpr>, Box<FExpr>, Box<FExpr>),
}

/// A compiled scalar combiner expression over (accumulator, value).
#[derive(Clone, Debug)]
enum SExpr {
    Acc,
    Val,
    Const(f32),
    Un(UnaryOp, Box<SExpr>),
    Bin(BinOp, Box<SExpr>, Box<SExpr>),
    Cmp(CmpOp, Box<SExpr>, Box<SExpr>),
    Sel(Box<SExpr>, Box<SExpr>, Box<SExpr>),
}

fn eval_sexpr(e: &SExpr, acc: f32, v: f32) -> f32 {
    match e {
        SExpr::Acc => acc,
        SExpr::Val => v,
        SExpr::Const(c) => *c,
        SExpr::Un(op, a) => op.apply(eval_sexpr(a, acc, v)),
        SExpr::Bin(op, a, b) => op.apply(eval_sexpr(a, acc, v), eval_sexpr(b, acc, v)),
        SExpr::Cmp(op, a, b) => {
            if op.apply(eval_sexpr(a, acc, v), eval_sexpr(b, acc, v)) {
                1.0
            } else {
                0.0
            }
        }
        SExpr::Sel(c, a, b) => {
            if eval_sexpr(c, acc, v) != 0.0 {
                eval_sexpr(a, acc, v)
            } else {
                eval_sexpr(b, acc, v)
            }
        }
    }
}

/// Reduce / reduce-window combining function, resolved at compile time.
#[derive(Clone, Debug)]
enum Combiner {
    Add,
    Mul,
    Max,
    Min,
    Generic(SExpr),
}

fn comb_apply(c: &Combiner, acc: f32, v: f32) -> f32 {
    match c {
        Combiner::Add => acc + v,
        Combiner::Mul => acc * v,
        Combiner::Max => acc.max(v),
        Combiner::Min => acc.min(v),
        Combiner::Generic(se) => eval_sexpr(se, acc, v),
    }
}

/// A strided gather (one loop serves broadcast and transpose):
/// `out[li] = src[Σ_d ((li / ostr[d]) % out_dims[d]) * sstr[d]]`.
#[derive(Clone, Debug)]
struct GatherSpec {
    out_dims: Vec<usize>,
    ostr: Vec<usize>,
    sstr: Vec<usize>,
    n: usize,
}

/// Shape plan for a `reduce` step.
#[derive(Clone, Debug)]
enum ReduceShape {
    /// Reduced dims are exactly the trailing dims: contiguous rows.
    Rows { rows: usize, cols: usize },
    /// General scatter-accumulate; `kept` maps an input dim to its output
    /// stride.
    Scatter { in_dims: Vec<usize>, istr: Vec<usize>, kept: Vec<(usize, usize)>, out_n: usize },
}

/// One executable step. `out` is a flat node id during compilation and an
/// arena slot id in the finished plan.
#[derive(Clone, Debug)]
enum Step {
    Fused {
        expr: FExpr,
        out: usize,
        n: usize,
    },
    Gather {
        src: Src,
        out: usize,
        spec: GatherSpec,
    },
    Reduce {
        src: Src,
        init: Src,
        out: usize,
        comb: Combiner,
        shape: ReduceShape,
    },
    /// Prefix-scan fast path of `reduce-window` (how XLA lowers cumsum).
    Scan {
        src: Src,
        init: Src,
        out: usize,
        comb: Combiner,
        n: usize,
        len: usize,
        sstride: usize,
        reverse: bool,
    },
    ReduceWindow {
        src: Src,
        init: Src,
        out: usize,
        comb: Combiner,
        in_dims: Vec<usize>,
        istr: Vec<usize>,
        out_dims: Vec<usize>,
        ostr: Vec<usize>,
        wsize: Vec<usize>,
        wstr: Vec<usize>,
        wstride: Vec<usize>,
        pad: Vec<(usize, usize)>,
    },
    Dot {
        lhs: Src,
        lspec: GatherSpec,
        rhs: Src,
        rspec: GatherSpec,
        out: usize,
        b: usize,
        m: usize,
        k: usize,
        n: usize,
    },
    /// `dynamic-slice`: copy a `sizes`-shaped window out of `src`, with
    /// runtime scalar start indices (clamped to keep the window in
    /// bounds, per HLO semantics).
    DynamicSlice {
        src: Src,
        starts: Vec<Src>,
        out: usize,
        in_dims: Vec<usize>,
        istr: Vec<usize>,
        sizes: Vec<usize>,
        ostr: Vec<usize>,
        n: usize,
    },
    /// `while`: run `body` on the carried state until `cond` returns 0.
    /// The condition and body compile to nested plans whose inputs are the
    /// flattened state elements; `outs` are this step's output nodes, one
    /// per element (the only multi-output step).
    While {
        cond: Box<ExecutablePlan>,
        body: Box<ExecutablePlan>,
        state: Vec<Src>,
        outs: Vec<usize>,
        elem_dims: Vec<Vec<usize>>,
        elem_dtypes: Vec<DType>,
        /// Index into [`PlanScratch::whiles`] for the nested scratches.
        scratch_idx: usize,
    },
}

/// Reusable execution scratch: the arena slots plus pooled temporaries.
/// Callers that execute a plan many times (benches, suite workers) can
/// reuse one scratch to skip even the per-call arena allocation.
#[derive(Default)]
pub struct PlanScratch {
    slots: Vec<Vec<f32>>,
    /// Chunk-sized temporaries for fused expression evaluation.
    pool: Vec<Vec<f32>>,
    /// Full-tensor temporaries (dot operand gathers).
    big: Vec<Vec<f32>>,
    /// Nested condition/body scratches for `while` steps (indexed by the
    /// step's `scratch_idx`), so repeat executions amortize the loop
    /// arenas too.
    whiles: Vec<WhileScratch>,
}

/// The two nested scratches a `while` step executes with.
#[derive(Default)]
struct WhileScratch {
    cond: PlanScratch,
    body: PlanScratch,
}

/// One level of the step dependency DAG: every step in a wave is mutually
/// hazard-free on the slot arena, so the wave may execute concurrently.
/// `While` steps are scheduled into their wave but always run serially
/// (after the wave's parallel batch) — their nested plans own mutable
/// per-step scratch state.
#[derive(Clone, Debug)]
struct Wave {
    steps: Vec<usize>,
    whiles: Vec<usize>,
}

/// A compiled, executable HLO module. Plain data (`Send + Sync`): many
/// worker threads can execute the same plan concurrently.
#[derive(Clone, Debug)]
pub struct ExecutablePlan {
    steps: Vec<Step>,
    consts: Vec<Tensor>,
    slot_caps: Vec<usize>,
    /// Output sources with their dims and logical dtype.
    roots: Vec<(Src, Vec<usize>, DType)>,
    param_dims: Vec<Vec<usize>>,
    /// Wave schedule over the step DAG (see [`Wave`]); executed instead of
    /// the serial step list when `parallel` is set and the worker pool is
    /// wider than one thread.
    waves: Vec<Wave>,
    parallel: bool,
}

// ------------------------------------------------------------- flattening

/// One instruction after call inlining: the parsed instruction (for its
/// attributes) plus operand links as flat node ids.
struct FlatInstr {
    instr: Instr,
    ops: Vec<usize>,
    dims: Vec<usize>,
    /// Entry parameter index, when this node is an entry parameter.
    param: Option<usize>,
    /// Set on a `while` step's first output node (the anchor): the flat
    /// node ids of ALL its state-element outputs, in element order. The
    /// remaining output nodes are markers whose single operand is the
    /// anchor (so liveness and dead-code elimination see the dependency).
    while_outs: Option<Vec<usize>>,
}

/// A flattened value: a single array node, or a flat tuple of array
/// nodes (`tuple`, tuple-returning `call`, `while` results). Tuples never
/// materialize — `get-tuple-element` resolves to the element node at
/// compile time.
#[derive(Clone, Debug)]
enum NodeVal {
    One(usize),
    Tup(Vec<usize>),
}

fn numel(dims: &[usize]) -> usize {
    dims.iter().product()
}

fn array_dims(ins: &Instr) -> Result<Vec<usize>, String> {
    Ok(ins.shape.array().map_err(|e| format!("{}: {e}", ins.name))?.dims.clone())
}

const MAX_INLINE_DEPTH: usize = 64;

/// Inline computation `ci` (with `args` as its parameter values) into
/// `nodes`, returning the local-index -> value map. `tuple`,
/// `get-tuple-element`, and tuple-returning `call`s resolve at compile
/// time to flat tuples of node ids; `while` pushes one output node per
/// state element (see [`FlatInstr::while_outs`]).
fn flatten(
    m: &Module,
    ci: usize,
    args: &[NodeVal],
    nodes: &mut Vec<FlatInstr>,
    depth: usize,
) -> Result<Vec<Option<NodeVal>>, String> {
    if depth > MAX_INLINE_DEPTH {
        return Err("call nesting exceeds the inlining depth limit".to_string());
    }
    let comp = &m.computations[ci];
    if args.len() != comp.params.len() {
        return Err(format!(
            "computation '{}' takes {} arguments, got {}",
            comp.name,
            comp.params.len(),
            args.len()
        ));
    }
    let mut local: Vec<Option<NodeVal>> = vec![None; comp.instrs.len()];
    for (li, ins) in comp.instrs.iter().enumerate() {
        let one = |local: &[Option<NodeVal>], o: &usize| -> Result<usize, String> {
            match &local[*o] {
                Some(NodeVal::One(id)) => Ok(*id),
                Some(NodeVal::Tup(_)) => Err(format!(
                    "{}: tuple-valued operand (nested tuples are not supported)",
                    ins.name
                )),
                None => Err(format!("{}: operand evaluated out of order", ins.name)),
            }
        };
        match &ins.opcode {
            Opcode::Parameter => {
                let pi = ins
                    .param_index
                    .ok_or_else(|| format!("{}: parameter without index", ins.name))?;
                local[li] = Some(
                    args.get(pi)
                        .cloned()
                        .ok_or_else(|| format!("{}: parameter index {pi} out of range", ins.name))?,
                );
            }
            Opcode::Call => {
                let target = ins
                    .to_apply
                    .as_deref()
                    .ok_or_else(|| format!("{}: call without to_apply", ins.name))?;
                let tci = m
                    .computation_index(target)
                    .ok_or_else(|| format!("{}: unknown computation '{target}'", ins.name))?;
                let mut call_args = Vec::with_capacity(ins.operands.len());
                for o in &ins.operands {
                    call_args.push(NodeVal::One(one(&local, o)?));
                }
                let sub = flatten(m, tci, &call_args, nodes, depth + 1)?;
                let root = m.computations[tci].root;
                local[li] = Some(sub[root].clone().ok_or_else(|| {
                    format!("{}: called computation '{target}' produced no value", ins.name)
                })?);
            }
            Opcode::Tuple => {
                let mut elems = Vec::with_capacity(ins.operands.len());
                for o in &ins.operands {
                    elems.push(one(&local, o)?);
                }
                local[li] = Some(NodeVal::Tup(elems));
            }
            Opcode::GetTupleElement => {
                let k = ins
                    .tuple_index
                    .ok_or_else(|| format!("{}: get-tuple-element without index", ins.name))?;
                let o = ins
                    .operands
                    .first()
                    .ok_or_else(|| format!("{}: missing operand 0", ins.name))?;
                match &local[*o] {
                    Some(NodeVal::Tup(elems)) => {
                        let id = *elems.get(k).ok_or_else(|| {
                            format!(
                                "{}: tuple index {k} out of range ({} elements)",
                                ins.name,
                                elems.len()
                            )
                        })?;
                        local[li] = Some(NodeVal::One(id));
                    }
                    Some(NodeVal::One(_)) => {
                        return Err(format!("{}: operand is not tuple-valued", ins.name))
                    }
                    None => {
                        return Err(format!("{}: operand evaluated out of order", ins.name))
                    }
                }
            }
            Opcode::While => {
                let o = ins
                    .operands
                    .first()
                    .ok_or_else(|| format!("{}: missing operand 0", ins.name))?;
                let state: Vec<usize> = match &local[*o] {
                    Some(NodeVal::One(id)) => vec![*id],
                    Some(NodeVal::Tup(elems)) => elems.clone(),
                    None => {
                        return Err(format!("{}: operand evaluated out of order", ins.name))
                    }
                };
                let elem_shapes = match &ins.shape {
                    InstrShape::Array(s) => vec![s.clone()],
                    InstrShape::Tuple(ss) => ss.clone(),
                };
                if elem_shapes.len() != state.len() {
                    return Err(format!(
                        "{}: while carries {} state elements but declares {}",
                        ins.name,
                        state.len(),
                        elem_shapes.len()
                    ));
                }
                for (k, (s, &sid)) in elem_shapes.iter().zip(&state).enumerate() {
                    if nodes[sid].dims != s.dims {
                        return Err(format!(
                            "{}: state element {k} has shape {:?}, while declares {:?}",
                            ins.name, nodes[sid].dims, s.dims
                        ));
                    }
                }
                let first = nodes.len();
                let ids: Vec<usize> = (first..first + elem_shapes.len()).collect();
                for (k, s) in elem_shapes.iter().enumerate() {
                    let mut wi = ins.clone();
                    wi.shape = InstrShape::Array(s.clone());
                    nodes.push(FlatInstr {
                        instr: wi,
                        ops: if k == 0 { state.clone() } else { vec![first] },
                        dims: s.dims.clone(),
                        param: None,
                        while_outs: if k == 0 { Some(ids.clone()) } else { None },
                    });
                }
                local[li] = Some(if matches!(ins.shape, InstrShape::Array(_)) {
                    NodeVal::One(ids[0])
                } else {
                    NodeVal::Tup(ids)
                });
            }
            _ => {
                let mut ops = Vec::with_capacity(ins.operands.len());
                for o in &ins.operands {
                    ops.push(one(&local, o)?);
                }
                let dims = array_dims(ins)?;
                nodes.push(FlatInstr {
                    instr: ins.clone(),
                    ops,
                    dims,
                    param: None,
                    while_outs: None,
                });
                local[li] = Some(NodeVal::One(nodes.len() - 1));
            }
        }
    }
    Ok(local)
}

// ---------------------------------------------------------- classification

/// Build-time representation of a node's value.
enum Repr {
    Pending,
    /// Inline-able elementwise expression (single consumer, not yet emitted).
    Expr(FExpr),
    /// Materialized: a step output, input, or constant.
    Mat(Src),
    /// Expression moved into its consumer (or dead code).
    Taken,
}

struct BuildState {
    repr: Vec<Repr>,
    consts: Vec<Tensor>,
    steps: Vec<Step>,
    /// Number of `while` steps emitted so far (allocates scratch indices).
    while_count: usize,
}

impl BuildState {
    /// The node's value as a materialized source, emitting its pending
    /// fused step if needed.
    fn mat_src(&mut self, nodes: &[FlatInstr], a: usize) -> Result<Src, String> {
        match &self.repr[a] {
            Repr::Mat(s) => Ok(*s),
            Repr::Expr(_) => {
                let taken = std::mem::replace(&mut self.repr[a], Repr::Mat(Src::Buf(a)));
                let expr = match taken {
                    Repr::Expr(e) => e,
                    _ => unreachable!(),
                };
                self.steps.push(Step::Fused { expr, out: a, n: numel(&nodes[a].dims) });
                Ok(Src::Buf(a))
            }
            _ => Err(format!("internal: node {a} read before it was computed")),
        }
    }

    /// The node's value as an expression operand. Single-use expressions
    /// move; materialized values become leaves.
    fn operand_expr(&mut self, a: usize) -> Result<FExpr, String> {
        match &self.repr[a] {
            Repr::Mat(s) => Ok(FExpr::Leaf(*s)),
            Repr::Expr(_) => match std::mem::replace(&mut self.repr[a], Repr::Taken) {
                Repr::Expr(e) => Ok(e),
                _ => unreachable!(),
            },
            _ => Err(format!("internal: node {a} read before it was computed")),
        }
    }

    /// Record an elementwise node: keep it inline while it has a single
    /// consumer, otherwise emit its fused step now.
    fn finish_elementwise(&mut self, i: usize, e: FExpr, uses: usize, n: usize) {
        if uses > 1 {
            self.steps.push(Step::Fused { expr: e, out: i, n });
            self.repr[i] = Repr::Mat(Src::Buf(i));
        } else {
            self.repr[i] = Repr::Expr(e);
        }
    }
}

fn unary_of(op: &Opcode) -> Option<UnaryOp> {
    Some(match op {
        Opcode::Exponential => UnaryOp::Exp,
        Opcode::Log => UnaryOp::Ln,
        Opcode::Tanh => UnaryOp::Tanh,
        Opcode::Sqrt => UnaryOp::Sqrt,
        Opcode::Rsqrt => UnaryOp::Rsqrt,
        Opcode::Negate => UnaryOp::Neg,
        Opcode::Abs => UnaryOp::Abs,
        Opcode::Floor => UnaryOp::Floor,
        Opcode::Ceil => UnaryOp::Ceil,
        Opcode::Sign => UnaryOp::Sign,
        Opcode::Logistic => UnaryOp::Logistic,
        _ => return None,
    })
}

fn binary_of(op: &Opcode) -> Option<BinOp> {
    Some(match op {
        Opcode::Add => BinOp::Add,
        Opcode::Subtract => BinOp::Sub,
        Opcode::Multiply => BinOp::Mul,
        Opcode::Divide => BinOp::Div,
        Opcode::Maximum => BinOp::Max,
        Opcode::Minimum => BinOp::Min,
        Opcode::Power => BinOp::Pow,
        _ => return None,
    })
}

fn cmp_of(dir: CmpDir) -> CmpOp {
    match dir {
        CmpDir::Eq => CmpOp::Eq,
        CmpDir::Ne => CmpOp::Ne,
        CmpDir::Ge => CmpOp::Ge,
        CmpDir::Gt => CmpOp::Gt,
        CmpDir::Le => CmpOp::Le,
        CmpDir::Lt => CmpOp::Lt,
    }
}

/// Validate `perm` and build the gather that permutes `in_dims` by it.
fn perm_spec(in_dims: &[usize], perm: &[usize]) -> Result<GatherSpec, String> {
    let rank = in_dims.len();
    if perm.len() != rank {
        return Err(format!("permutation {perm:?} does not match rank {rank}"));
    }
    let mut seen = vec![false; rank];
    for &p in perm {
        if p >= rank || seen[p] {
            return Err(format!("invalid permutation {perm:?} for rank {rank}"));
        }
        seen[p] = true;
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let in_strides = kernels::row_major_strides(in_dims);
    let sstr: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
    let ostr = kernels::row_major_strides(&out_dims);
    let n = numel(&out_dims);
    Ok(GatherSpec { out_dims, ostr, sstr, n })
}

/// Resolve a reduce/reduce-window combiner computation.
fn compile_combiner(m: &Module, ins: &Instr) -> Result<Combiner, String> {
    let name = ins
        .to_apply
        .as_deref()
        .ok_or_else(|| format!("{}: reduce without to_apply", ins.name))?;
    let ci = m
        .computation_index(name)
        .ok_or_else(|| format!("{}: unknown combiner computation '{name}'", ins.name))?;
    let comp = &m.computations[ci];
    let root = &comp.instrs[comp.root];
    if comp.params.len() == 2 && root.operands.len() == 2 {
        let (p0, p1) = (comp.params[0], comp.params[1]);
        let (a, b) = (root.operands[0], root.operands[1]);
        if (a == p0 && b == p1) || (a == p1 && b == p0) {
            match root.opcode {
                Opcode::Add => return Ok(Combiner::Add),
                Opcode::Multiply => return Ok(Combiner::Mul),
                Opcode::Maximum => return Ok(Combiner::Max),
                Opcode::Minimum => return Ok(Combiner::Min),
                _ => {}
            }
        }
    }
    if comp.params.len() != 2 {
        return Err(format!(
            "{}: combiner '{name}' takes {} parameters, expected 2",
            ins.name,
            comp.params.len()
        ));
    }
    let se = compile_scalar_comp(m, ci, vec![SExpr::Acc, SExpr::Val], 0)
        .map_err(|e| format!("{}: combiner '{name}': {e}", ins.name))?;
    Ok(Combiner::Generic(se))
}

/// Compile a scalar computation (every value numel 1) into an [`SExpr`]
/// over the provided parameter expressions.
fn compile_scalar_comp(
    m: &Module,
    ci: usize,
    args: Vec<SExpr>,
    depth: usize,
) -> Result<SExpr, String> {
    if depth > MAX_INLINE_DEPTH {
        return Err("call nesting exceeds the inlining depth limit".to_string());
    }
    let comp = &m.computations[ci];
    if args.len() != comp.params.len() {
        return Err(format!(
            "computation '{}' takes {} arguments, got {}",
            comp.name,
            comp.params.len(),
            args.len()
        ));
    }
    let mut local: Vec<Option<SExpr>> = (0..comp.instrs.len()).map(|_| None).collect();
    for (li, ins) in comp.instrs.iter().enumerate() {
        let get = |o: usize| -> Result<SExpr, String> {
            let idx = *ins
                .operands
                .get(o)
                .ok_or_else(|| format!("{}: missing operand {o}", ins.name))?;
            local[idx].clone().ok_or_else(|| format!("{}: operand out of order", ins.name))
        };
        let dims = array_dims(ins)?;
        if numel(&dims) != 1 {
            return Err(format!("{}: non-scalar value in scalar combiner", ins.name));
        }
        let e = match &ins.opcode {
            Opcode::Parameter => {
                let pi = ins
                    .param_index
                    .ok_or_else(|| format!("{}: parameter without index", ins.name))?;
                args.get(pi)
                    .cloned()
                    .ok_or_else(|| format!("{}: parameter index {pi} out of range", ins.name))?
            }
            Opcode::Constant => {
                let lit = ins
                    .literal
                    .as_ref()
                    .ok_or_else(|| format!("{}: constant without literal", ins.name))?;
                SExpr::Const(lit[0])
            }
            Opcode::Copy | Opcode::Convert | Opcode::Reshape | Opcode::Broadcast => get(0)?,
            Opcode::Compare => {
                let dir = ins
                    .direction
                    .ok_or_else(|| format!("{}: compare without direction", ins.name))?;
                SExpr::Cmp(cmp_of(dir), Box::new(get(0)?), Box::new(get(1)?))
            }
            Opcode::Select => {
                SExpr::Sel(Box::new(get(0)?), Box::new(get(1)?), Box::new(get(2)?))
            }
            Opcode::Call => {
                let target = ins
                    .to_apply
                    .as_deref()
                    .ok_or_else(|| format!("{}: call without to_apply", ins.name))?;
                let tci = m
                    .computation_index(target)
                    .ok_or_else(|| format!("{}: unknown computation '{target}'", ins.name))?;
                let mut call_args = Vec::with_capacity(ins.operands.len());
                for o in 0..ins.operands.len() {
                    call_args.push(get(o)?);
                }
                compile_scalar_comp(m, tci, call_args, depth + 1)?
            }
            op => {
                if let Some(u) = unary_of(op) {
                    SExpr::Un(u, Box::new(get(0)?))
                } else if let Some(b) = binary_of(op) {
                    SExpr::Bin(b, Box::new(get(0)?), Box::new(get(1)?))
                } else {
                    return Err(format!(
                        "{}: opcode outside the scalar-combiner op set",
                        ins.name
                    ));
                }
            }
        };
        local[li] = Some(e);
    }
    local[comp.root]
        .clone()
        .ok_or_else(|| format!("computation '{}': root was never built", comp.name))
}

// ------------------------------------------------------------ compilation

impl ExecutablePlan {
    /// Compile with default options (arena on).
    pub fn compile(m: &Module) -> Result<ExecutablePlan, String> {
        ExecutablePlan::compile_with(m, PlanOptions::default())
    }

    /// Compile the module's ENTRY computation with explicit options.
    pub fn compile_with(m: &Module, opts: PlanOptions) -> Result<ExecutablePlan, String> {
        ExecutablePlan::compile_computation(m, m.entry, opts, 0)
    }

    /// Compile one computation of `m` into a plan. Array-shaped parameters
    /// bind one plan input each; a tuple-shaped parameter (the carried
    /// state of a `while` condition/body) binds one plan input per
    /// element, in element order.
    fn compile_computation(
        m: &Module,
        ci: usize,
        opts: PlanOptions,
        depth: usize,
    ) -> Result<ExecutablePlan, String> {
        if depth > MAX_INLINE_DEPTH {
            return Err("while nesting exceeds the inlining depth limit".to_string());
        }
        let comp = &m.computations[ci];
        let mut nodes: Vec<FlatInstr> = Vec::new();
        let mut args: Vec<NodeVal> = Vec::new();
        let mut param_dims: Vec<Vec<usize>> = Vec::new();
        for &idx in &comp.params {
            let ins = &comp.instrs[idx];
            match ins.shape.clone() {
                InstrShape::Array(s) => {
                    nodes.push(FlatInstr {
                        instr: ins.clone(),
                        ops: Vec::new(),
                        dims: s.dims.clone(),
                        param: Some(param_dims.len()),
                        while_outs: None,
                    });
                    args.push(NodeVal::One(nodes.len() - 1));
                    param_dims.push(s.dims);
                }
                InstrShape::Tuple(shapes) => {
                    let mut elems = Vec::with_capacity(shapes.len());
                    for s in shapes {
                        let mut pi = ins.clone();
                        pi.shape = InstrShape::Array(s.clone());
                        nodes.push(FlatInstr {
                            instr: pi,
                            ops: Vec::new(),
                            dims: s.dims.clone(),
                            param: Some(param_dims.len()),
                            while_outs: None,
                        });
                        elems.push(nodes.len() - 1);
                        param_dims.push(s.dims);
                    }
                    args.push(NodeVal::Tup(elems));
                }
            }
        }
        let local = flatten(m, ci, &args, &mut nodes, depth)?;

        let root_ids: Vec<usize> = match local[comp.root].clone() {
            Some(NodeVal::Tup(ids)) => ids,
            Some(NodeVal::One(id)) => vec![id],
            None => {
                return Err(format!("computation '{}': root was never flattened", comp.name))
            }
        };

        let mut use_count = vec![0usize; nodes.len()];
        for fi in &nodes {
            for &o in &fi.ops {
                use_count[o] += 1;
            }
        }
        for &r in &root_ids {
            use_count[r] += 1;
        }
        // transitive dead-code elimination: walk backwards (operands always
        // precede consumers) removing the edges of dead nodes, so a chain
        // feeding only dead consumers is dropped all the way down — not
        // just its last link
        for i in (0..nodes.len()).rev() {
            if use_count[i] == 0 {
                for &o in &nodes[i].ops {
                    use_count[o] -= 1;
                }
            }
        }

        let mut st = BuildState {
            repr: (0..nodes.len()).map(|_| Repr::Pending).collect(),
            consts: Vec::new(),
            steps: Vec::new(),
            while_count: 0,
        };
        for i in 0..nodes.len() {
            compile_node(m, &nodes, i, use_count[i], &mut st, opts, depth)?;
        }

        let mut roots = Vec::with_capacity(root_ids.len());
        for &r in &root_ids {
            let src = st.mat_src(&nodes, r)?;
            let dt = nodes[r]
                .instr
                .shape
                .array()
                .map_err(|e| format!("{}: {e}", nodes[r].instr.name))?
                .elem
                .dtype();
            roots.push((src, nodes[r].dims.clone(), dt));
        }

        let (steps, slot_caps, root_srcs) =
            assign_slots(st.steps, roots, &nodes, opts.reuse_buffers)?;
        let waves = build_waves(&steps, slot_caps.len());

        Ok(ExecutablePlan {
            steps,
            consts: st.consts,
            slot_caps,
            roots: root_srcs,
            param_dims,
            waves,
            parallel: opts.parallel,
        })
    }

    /// Number of executable steps (post fusion).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Number of arena buffers the plan executes with.
    pub fn slot_count(&self) -> usize {
        self.slot_caps.len()
    }

    /// Number of levels in the wave schedule: the plan's critical-path
    /// length over the step DAG. `wave_count() == step_count()` means a
    /// fully serial chain (no step-level parallelism to exploit).
    pub fn wave_count(&self) -> usize {
        self.waves.len()
    }
}

/// Compile one flat node into the build state.
fn compile_node(
    m: &Module,
    nodes: &[FlatInstr],
    i: usize,
    uses: usize,
    st: &mut BuildState,
    opts: PlanOptions,
    depth: usize,
) -> Result<(), String> {
    if let Some(pi) = nodes[i].param {
        st.repr[i] = Repr::Mat(Src::Input(pi));
        return Ok(());
    }
    if matches!(nodes[i].instr.opcode, Opcode::While) {
        return compile_while(m, nodes, i, uses, st, opts, depth);
    }
    if uses == 0 {
        // dead code: all ops are pure, skip the node entirely
        st.repr[i] = Repr::Taken;
        return Ok(());
    }
    let name = nodes[i].instr.name.clone();
    let out_dims = nodes[i].dims.clone();
    let n_out = numel(&out_dims);
    let ops = nodes[i].ops.clone();
    let opd = |k: usize| -> Result<usize, String> {
        ops.get(k).copied().ok_or_else(|| format!("{name}: missing operand {k}"))
    };
    let opcode = nodes[i].instr.opcode.clone();
    match &opcode {
        Opcode::Parameter => {
            return Err(format!("{name}: parameter was not bound to an argument"))
        }
        Opcode::Constant => {
            let lit = nodes[i]
                .instr
                .literal
                .clone()
                .ok_or_else(|| format!("{name}: constant without literal"))?;
            st.consts.push(Tensor::new(out_dims, DType::F32, lit));
            st.repr[i] = Repr::Mat(Src::Const(st.consts.len() - 1));
        }
        Opcode::Copy | Opcode::Reshape => {
            let a = opd(0)?;
            if numel(&nodes[a].dims) != n_out {
                return Err(format!(
                    "{name}: cannot reshape {} elements into {n_out}",
                    numel(&nodes[a].dims)
                ));
            }
            let e = st.operand_expr(a)?;
            st.finish_elementwise(i, e, uses, n_out);
        }
        Opcode::Convert => {
            let a = opd(0)?;
            if numel(&nodes[a].dims) != n_out {
                return Err(format!(
                    "{name}: cannot convert {} elements into {n_out}",
                    numel(&nodes[a].dims)
                ));
            }
            let src_elem =
                nodes[a].instr.shape.array().map_err(|e| format!("{name}: {e}"))?.elem;
            let dst_elem =
                nodes[i].instr.shape.array().map_err(|e| format!("{name}: {e}"))?.elem;
            let e = st.operand_expr(a)?;
            let e = match super::convert_op(src_elem, dst_elem) {
                None => e,
                Some(u) => FExpr::Un(u, Box::new(e)),
            };
            st.finish_elementwise(i, e, uses, n_out);
        }
        Opcode::Iota => {
            let dim = nodes[i]
                .instr
                .iota_dim
                .ok_or_else(|| format!("{name}: iota without iota_dimension"))?;
            if dim >= out_dims.len() {
                return Err(format!(
                    "{name}: iota_dimension {dim} out of range for rank {}",
                    out_dims.len()
                ));
            }
            // iota is fully determined by its shape: fold it into a
            // compile-time constant (the evaluator materializes the same
            // values per call — see kernels::iota_fill)
            let ostr = kernels::row_major_strides(&out_dims);
            let mut data = vec![0f32; n_out];
            kernels::iota_fill(&mut data, &out_dims, &ostr, dim);
            let elem = nodes[i].instr.shape.array().map_err(|e| format!("{name}: {e}"))?.elem;
            st.consts.push(Tensor::new(out_dims, elem.dtype(), data));
            st.repr[i] = Repr::Mat(Src::Const(st.consts.len() - 1));
        }
        Opcode::DynamicSlice => {
            let a = opd(0)?;
            let in_dims = nodes[a].dims.clone();
            let rank = in_dims.len();
            let sizes = nodes[i].instr.slice_sizes.clone();
            if sizes.len() != rank {
                return Err(format!(
                    "{name}: dynamic_slice_sizes rank does not match operand rank {rank}"
                ));
            }
            if sizes != out_dims {
                return Err(format!(
                    "{name}: result shape {out_dims:?} does not match dynamic_slice_sizes {sizes:?}"
                ));
            }
            if ops.len() != rank + 1 {
                return Err(format!(
                    "{name}: expected {rank} start indices, found {}",
                    ops.len().saturating_sub(1)
                ));
            }
            for d in 0..rank {
                if sizes[d] > in_dims[d] {
                    return Err(format!(
                        "{name}: slice size {} exceeds operand dim {d} ({})",
                        sizes[d], in_dims[d]
                    ));
                }
                if numel(&nodes[opd(1 + d)?].dims) != 1 {
                    return Err(format!("{name}: start index {d} must be scalar"));
                }
            }
            let src = st.mat_src(nodes, a)?;
            let mut starts = Vec::with_capacity(rank);
            for d in 0..rank {
                starts.push(st.mat_src(nodes, ops[1 + d])?);
            }
            let istr = kernels::row_major_strides(&in_dims);
            let ostr = kernels::row_major_strides(&out_dims);
            st.steps.push(Step::DynamicSlice {
                src,
                starts,
                out: i,
                in_dims,
                istr,
                sizes,
                ostr,
                n: n_out,
            });
            st.repr[i] = Repr::Mat(Src::Buf(i));
        }
        Opcode::Compare => {
            let (a, b) = (opd(0)?, opd(1)?);
            if numel(&nodes[a].dims) != n_out || numel(&nodes[b].dims) != n_out {
                return Err(format!("{name}: operand shapes do not match result"));
            }
            let dir = nodes[i]
                .instr
                .direction
                .ok_or_else(|| format!("{name}: compare without direction"))?;
            let ea = st.operand_expr(a)?;
            let eb = st.operand_expr(b)?;
            let e = FExpr::Cmp(cmp_of(dir), Box::new(ea), Box::new(eb));
            st.finish_elementwise(i, e, uses, n_out);
        }
        Opcode::Select => {
            let (c, a, b) = (opd(0)?, opd(1)?, opd(2)?);
            for &o in &[c, a, b] {
                if numel(&nodes[o].dims) != n_out {
                    return Err(format!("{name}: select operand shapes disagree"));
                }
            }
            let ec = st.operand_expr(c)?;
            let ea = st.operand_expr(a)?;
            let eb = st.operand_expr(b)?;
            let e = FExpr::Sel(Box::new(ec), Box::new(ea), Box::new(eb));
            st.finish_elementwise(i, e, uses, n_out);
        }
        Opcode::Broadcast => {
            let a = opd(0)?;
            let in_dims = nodes[a].dims.clone();
            if numel(&in_dims) == 1 {
                // scalar fill: fold into the consumer as a splat
                let const_scalar = match &st.repr[a] {
                    Repr::Mat(Src::Const(k)) => Some(*k),
                    _ => None,
                };
                let e = match const_scalar {
                    Some(k) => FExpr::Splat(st.consts[k].data[0]),
                    None => FExpr::SplatLeaf(st.mat_src(nodes, a)?),
                };
                st.finish_elementwise(i, e, uses, n_out);
            } else {
                let dims_attr = nodes[i].instr.dimensions.clone().unwrap_or_default();
                if dims_attr.len() != in_dims.len() {
                    return Err(format!(
                        "{name}: dimensions {dims_attr:?} do not match operand rank {}",
                        in_dims.len()
                    ));
                }
                let in_strides = kernels::row_major_strides(&in_dims);
                let mut sstr = vec![0usize; out_dims.len()];
                for (bi, &od) in dims_attr.iter().enumerate() {
                    if od >= out_dims.len() {
                        return Err(format!("{name}: broadcast dimension {od} out of range"));
                    }
                    if in_dims[bi] != 1 {
                        if in_dims[bi] != out_dims[od] {
                            return Err(format!(
                                "{name}: operand dim {bi} ({}) does not match output dim {od} ({})",
                                in_dims[bi], out_dims[od]
                            ));
                        }
                        sstr[od] = in_strides[bi];
                    }
                }
                let ostr = kernels::row_major_strides(&out_dims);
                let src = st.mat_src(nodes, a)?;
                let spec = GatherSpec { out_dims, ostr, sstr, n: n_out };
                st.steps.push(Step::Gather { src, out: i, spec });
                st.repr[i] = Repr::Mat(Src::Buf(i));
            }
        }
        Opcode::Transpose => {
            let a = opd(0)?;
            let perm = nodes[i]
                .instr
                .dimensions
                .clone()
                .ok_or_else(|| format!("{name}: transpose without dimensions"))?;
            let spec =
                perm_spec(&nodes[a].dims, &perm).map_err(|e| format!("{name}: {e}"))?;
            if spec.out_dims != out_dims {
                return Err(format!(
                    "{name}: transpose produced {:?}, declared {:?}",
                    spec.out_dims, out_dims
                ));
            }
            let src = st.mat_src(nodes, a)?;
            st.steps.push(Step::Gather { src, out: i, spec });
            st.repr[i] = Repr::Mat(Src::Buf(i));
        }
        Opcode::Reduce => {
            let (a, iv) = (opd(0)?, opd(1)?);
            if numel(&nodes[iv].dims) != 1 {
                return Err(format!(
                    "{name}: init value must be scalar, got shape {:?}",
                    nodes[iv].dims
                ));
            }
            let comb = compile_combiner(m, &nodes[i].instr)?;
            let red = nodes[i]
                .instr
                .dimensions
                .clone()
                .ok_or_else(|| format!("{name}: reduce without dimensions"))?;
            let in_dims = nodes[a].dims.clone();
            let kept: Vec<usize> =
                (0..in_dims.len()).filter(|d| !red.contains(d)).collect();
            let kept_dims: Vec<usize> = kept.iter().map(|&d| in_dims[d]).collect();
            if kept_dims != out_dims {
                return Err(format!(
                    "{name}: reduce output shape {out_dims:?} does not match kept dims {kept_dims:?}"
                ));
            }
            let suffix = kept.iter().enumerate().all(|(j, &d)| j == d);
            let shape = if suffix {
                let rows = numel(&kept_dims);
                let cols = if rows == 0 { 0 } else { numel(&in_dims) / rows };
                ReduceShape::Rows { rows, cols }
            } else {
                let istr = kernels::row_major_strides(&in_dims);
                let ostr = kernels::row_major_strides(&out_dims);
                let kept_strides: Vec<(usize, usize)> =
                    kept.iter().enumerate().map(|(j, &d)| (d, ostr[j])).collect();
                ReduceShape::Scatter { in_dims, istr, kept: kept_strides, out_n: n_out }
            };
            let src = st.mat_src(nodes, a)?;
            let init = st.mat_src(nodes, iv)?;
            st.steps.push(Step::Reduce { src, init, out: i, comb, shape });
            st.repr[i] = Repr::Mat(Src::Buf(i));
        }
        Opcode::ReduceWindow => {
            let (a, iv) = (opd(0)?, opd(1)?);
            if numel(&nodes[iv].dims) != 1 {
                return Err(format!(
                    "{name}: init value must be scalar, got shape {:?}",
                    nodes[iv].dims
                ));
            }
            let comb = compile_combiner(m, &nodes[i].instr)?;
            let w = nodes[i]
                .instr
                .window
                .clone()
                .ok_or_else(|| format!("{name}: reduce-window without window attribute"))?;
            let in_dims = nodes[a].dims.clone();
            let rank = in_dims.len();
            if w.size.len() != rank || w.stride.len() != rank || w.pad.len() != rank {
                return Err(format!(
                    "{name}: window rank does not match operand rank {rank}"
                ));
            }
            let istr = kernels::row_major_strides(&in_dims);
            // prefix-scan detection (how XLA lowers cumsum/cumprod): every
            // dim pointwise except one whose window covers the whole dim,
            // padded so output i sees 0..=i (or i.. when reversed)
            let mut scan_dim: Option<(usize, bool)> = None;
            let mut scan_ok = out_dims == in_dims;
            if scan_ok {
                for d in 0..rank {
                    let full = in_dims[d];
                    if w.size[d] == 1 && w.stride[d] == 1 && w.pad[d] == (0, 0) {
                        continue;
                    }
                    if w.stride[d] == 1 && full > 0 && w.size[d] == full && scan_dim.is_none() {
                        if w.pad[d] == (full - 1, 0) {
                            scan_dim = Some((d, false));
                            continue;
                        }
                        if w.pad[d] == (0, full - 1) {
                            scan_dim = Some((d, true));
                            continue;
                        }
                    }
                    scan_ok = false;
                    break;
                }
            }
            let src = st.mat_src(nodes, a)?;
            let init = st.mat_src(nodes, iv)?;
            if scan_ok {
                if let Some((sd, reverse)) = scan_dim {
                    st.steps.push(Step::Scan {
                        src,
                        init,
                        out: i,
                        comb,
                        n: n_out,
                        len: in_dims[sd],
                        sstride: istr[sd],
                        reverse,
                    });
                    st.repr[i] = Repr::Mat(Src::Buf(i));
                    return Ok(());
                }
            }
            let ostr = kernels::row_major_strides(&out_dims);
            let wstr = kernels::row_major_strides(&w.size);
            st.steps.push(Step::ReduceWindow {
                src,
                init,
                out: i,
                comb,
                in_dims,
                istr,
                out_dims,
                ostr,
                wsize: w.size,
                wstr,
                wstride: w.stride,
                pad: w.pad,
            });
            st.repr[i] = Repr::Mat(Src::Buf(i));
        }
        Opcode::Dot => {
            let (a, b) = (opd(0)?, opd(1)?);
            let (ld, rd) = (nodes[a].dims.clone(), nodes[b].dims.clone());
            let ins = &nodes[i].instr;
            let (lb, rb) = (&ins.lhs_batch, &ins.rhs_batch);
            let (lc, rc) = (&ins.lhs_contract, &ins.rhs_contract);
            if lb.len() != rb.len() || lc.len() != rc.len() {
                return Err(format!(
                    "{name}: mismatched batch/contracting dimension counts"
                ));
            }
            for (&l, &r) in lb.iter().zip(rb) {
                if l >= ld.len() || r >= rd.len() || ld[l] != rd[r] {
                    return Err(format!("{name}: batch dims disagree"));
                }
            }
            for (&l, &r) in lc.iter().zip(rc) {
                if l >= ld.len() || r >= rd.len() || ld[l] != rd[r] {
                    return Err(format!("{name}: contracting dims disagree"));
                }
            }
            let lfree: Vec<usize> =
                (0..ld.len()).filter(|d| !lb.contains(d) && !lc.contains(d)).collect();
            let rfree: Vec<usize> =
                (0..rd.len()).filter(|d| !rb.contains(d) && !rc.contains(d)).collect();
            let mut lperm = lb.clone();
            lperm.extend_from_slice(&lfree);
            lperm.extend_from_slice(lc);
            let mut rperm = rb.clone();
            rperm.extend_from_slice(rc);
            rperm.extend_from_slice(&rfree);
            let lspec = perm_spec(&ld, &lperm).map_err(|e| format!("{name}: {e}"))?;
            let rspec = perm_spec(&rd, &rperm).map_err(|e| format!("{name}: {e}"))?;
            let bsz: usize = lb.iter().map(|&d| ld[d]).product();
            let ksz: usize = lc.iter().map(|&d| ld[d]).product();
            let msz: usize = lfree.iter().map(|&d| ld[d]).product();
            let nsz: usize = rfree.iter().map(|&d| rd[d]).product();
            if n_out != bsz * msz * nsz {
                return Err(format!(
                    "{name}: result shape does not match dot extents {bsz}x{msz}x{nsz}"
                ));
            }
            let lsrc = st.mat_src(nodes, a)?;
            let rsrc = st.mat_src(nodes, b)?;
            st.steps.push(Step::Dot {
                lhs: lsrc,
                lspec,
                rhs: rsrc,
                rspec,
                out: i,
                b: bsz,
                m: msz,
                k: ksz,
                n: nsz,
            });
            st.repr[i] = Repr::Mat(Src::Buf(i));
        }
        Opcode::Tuple | Opcode::GetTupleElement | Opcode::Call => {
            unreachable!("tuples, get-tuple-element and calls are resolved during flattening")
        }
        Opcode::Other(op) => {
            return Err(format!(
                "{name}: opcode '{op}' is outside the plan compiler's op set"
            ))
        }
        op => {
            // remaining opcodes are elementwise unary/binary
            if let Some(u) = unary_of(op) {
                let a = opd(0)?;
                if numel(&nodes[a].dims) != n_out {
                    return Err(format!(
                        "{name}: result numel {n_out} vs operand numel {}",
                        numel(&nodes[a].dims)
                    ));
                }
                let e = FExpr::Un(u, Box::new(st.operand_expr(a)?));
                st.finish_elementwise(i, e, uses, n_out);
            } else if let Some(bo) = binary_of(op) {
                let (a, b) = (opd(0)?, opd(1)?);
                if numel(&nodes[a].dims) != n_out || numel(&nodes[b].dims) != n_out {
                    return Err(format!("{name}: operand shapes do not match result"));
                }
                let ea = st.operand_expr(a)?;
                let eb = st.operand_expr(b)?;
                let e = FExpr::Bin(bo, Box::new(ea), Box::new(eb));
                st.finish_elementwise(i, e, uses, n_out);
            } else {
                return Err(format!("{name}: opcode {op:?} is not handled"));
            }
        }
    }
    Ok(())
}

/// Compile a `while` node group. Called on every output-element node; the
/// anchor (the node carrying [`FlatInstr::while_outs`]) emits the step and
/// materializes the representation of all its output elements, marker
/// nodes are no-ops.
fn compile_while(
    m: &Module,
    nodes: &[FlatInstr],
    i: usize,
    uses: usize,
    st: &mut BuildState,
    opts: PlanOptions,
    depth: usize,
) -> Result<(), String> {
    let Some(outs) = nodes[i].while_outs.clone() else {
        // marker element: its anchor either materialized it already, or
        // the whole while is dead
        if matches!(st.repr[i], Repr::Pending) {
            st.repr[i] = Repr::Taken;
        }
        return Ok(());
    };
    if uses == 0 {
        // the anchor's use count reaches zero only once every output
        // element is dead (markers reference the anchor), so the whole
        // loop can be dropped
        st.repr[i] = Repr::Taken;
        return Ok(());
    }
    let name = nodes[i].instr.name.clone();
    let cond_name = nodes[i]
        .instr
        .condition
        .clone()
        .ok_or_else(|| format!("{name}: while without condition"))?;
    let body_name = nodes[i]
        .instr
        .body
        .clone()
        .ok_or_else(|| format!("{name}: while without body"))?;
    let cci = m
        .computation_index(&cond_name)
        .ok_or_else(|| format!("{name}: unknown computation '{cond_name}'"))?;
    let bci = m
        .computation_index(&body_name)
        .ok_or_else(|| format!("{name}: unknown computation '{body_name}'"))?;
    let cond = ExecutablePlan::compile_computation(m, cci, opts, depth + 1)
        .map_err(|e| format!("{name}: condition '{cond_name}': {e}"))?;
    let body = ExecutablePlan::compile_computation(m, bci, opts, depth + 1)
        .map_err(|e| format!("{name}: body '{body_name}': {e}"))?;
    let elem_dims: Vec<Vec<usize>> = outs.iter().map(|&o| nodes[o].dims.clone()).collect();
    let mut elem_dtypes = Vec::with_capacity(outs.len());
    for &o in &outs {
        let elem = nodes[o].instr.shape.array().map_err(|e| format!("{name}: {e}"))?.elem;
        elem_dtypes.push(elem.dtype());
    }
    if cond.param_dims != elem_dims {
        return Err(format!(
            "{name}: condition '{cond_name}' takes {:?}, state is {elem_dims:?}",
            cond.param_dims
        ));
    }
    if body.param_dims != elem_dims {
        return Err(format!(
            "{name}: body '{body_name}' takes {:?}, state is {elem_dims:?}",
            body.param_dims
        ));
    }
    if cond.roots.len() != 1 || numel(&cond.roots[0].1) != 1 {
        return Err(format!(
            "{name}: condition '{cond_name}' must return a scalar pred"
        ));
    }
    if body.roots.len() != elem_dims.len()
        || body.roots.iter().zip(&elem_dims).any(|(r, d)| &r.1 != d)
    {
        return Err(format!(
            "{name}: body '{body_name}' returns {:?}, state is {elem_dims:?}",
            body.roots.iter().map(|r| r.1.clone()).collect::<Vec<_>>()
        ));
    }
    let mut state = Vec::with_capacity(nodes[i].ops.len());
    for k in 0..nodes[i].ops.len() {
        let sid = nodes[i].ops[k];
        state.push(st.mat_src(nodes, sid)?);
    }
    let scratch_idx = st.while_count;
    st.while_count += 1;
    st.steps.push(Step::While {
        cond: Box::new(cond),
        body: Box::new(body),
        state,
        outs: outs.clone(),
        elem_dims,
        elem_dtypes,
        scratch_idx,
    });
    for &o in &outs {
        st.repr[o] = Repr::Mat(Src::Buf(o));
    }
    Ok(())
}

// --------------------------------------------------- liveness + slot arena

fn expr_bufs(e: &FExpr, out: &mut Vec<usize>) {
    match e {
        FExpr::Leaf(Src::Buf(b)) | FExpr::SplatLeaf(Src::Buf(b)) => out.push(*b),
        FExpr::Leaf(_) | FExpr::SplatLeaf(_) | FExpr::Splat(_) => {}
        FExpr::Un(_, a) => expr_bufs(a, out),
        FExpr::Bin(_, a, b) | FExpr::Cmp(_, a, b) => {
            expr_bufs(a, out);
            expr_bufs(b, out);
        }
        FExpr::Sel(c, a, b) => {
            expr_bufs(c, out);
            expr_bufs(a, out);
            expr_bufs(b, out);
        }
    }
}

fn push_buf(src: &Src, out: &mut Vec<usize>) {
    if let Src::Buf(b) = src {
        out.push(*b);
    }
}

/// Node ids read by a step (as `Buf` sources).
fn step_inputs(step: &Step, out: &mut Vec<usize>) {
    out.clear();
    match step {
        Step::Fused { expr, .. } => expr_bufs(expr, out),
        Step::Gather { src, .. } => push_buf(src, out),
        Step::Reduce { src, init, .. }
        | Step::Scan { src, init, .. }
        | Step::ReduceWindow { src, init, .. } => {
            push_buf(src, out);
            push_buf(init, out);
        }
        Step::Dot { lhs, rhs, .. } => {
            push_buf(lhs, out);
            push_buf(rhs, out);
        }
        Step::DynamicSlice { src, starts, .. } => {
            push_buf(src, out);
            for s in starts {
                push_buf(s, out);
            }
        }
        Step::While { state, .. } => {
            for s in state {
                push_buf(s, out);
            }
        }
    }
}

/// Node ids a step writes (`While` is the only multi-output step).
fn step_outs(step: &Step, buf: &mut Vec<usize>) {
    buf.clear();
    match step {
        Step::While { outs, .. } => buf.extend_from_slice(outs),
        other => buf.push(step_single_out(other)),
    }
}

/// The single output node of any non-`While` step (allocation-free; the
/// hot execution path must not build a `Vec` per step).
fn step_single_out(step: &Step) -> usize {
    match step {
        Step::Fused { out, .. }
        | Step::Gather { out, .. }
        | Step::Reduce { out, .. }
        | Step::Scan { out, .. }
        | Step::ReduceWindow { out, .. }
        | Step::Dot { out, .. }
        | Step::DynamicSlice { out, .. } => *out,
        Step::While { .. } => unreachable!("while is multi-output"),
    }
}

fn rewrite_src(src: &mut Src, map: &[usize]) -> Result<(), String> {
    if let Src::Buf(b) = src {
        let slot = map[*b];
        if slot == usize::MAX {
            return Err(format!("internal: node {b} was never assigned a slot"));
        }
        *src = Src::Buf(slot);
    }
    Ok(())
}

fn rewrite_expr(e: &mut FExpr, map: &[usize]) -> Result<(), String> {
    match e {
        FExpr::Leaf(s) | FExpr::SplatLeaf(s) => rewrite_src(s, map),
        FExpr::Splat(_) => Ok(()),
        FExpr::Un(_, a) => rewrite_expr(a, map),
        FExpr::Bin(_, a, b) | FExpr::Cmp(_, a, b) => {
            rewrite_expr(a, map)?;
            rewrite_expr(b, map)
        }
        FExpr::Sel(c, a, b) => {
            rewrite_expr(c, map)?;
            rewrite_expr(a, map)?;
            rewrite_expr(b, map)
        }
    }
}

/// Last-use liveness scan: assign every step output an arena slot,
/// recycling slots of operands past their last use (when `reuse` is on),
/// then rewrite all node ids to slot ids.
#[allow(clippy::type_complexity)]
fn assign_slots(
    mut steps: Vec<Step>,
    roots: Vec<(Src, Vec<usize>, DType)>,
    nodes: &[FlatInstr],
    reuse: bool,
) -> Result<(Vec<Step>, Vec<usize>, Vec<(Src, Vec<usize>, DType)>), String> {
    let mut last_use: Vec<Option<usize>> = vec![None; nodes.len()];
    let mut scratch = Vec::new();
    for (s, step) in steps.iter().enumerate() {
        step_inputs(step, &mut scratch);
        for &id in &scratch {
            last_use[id] = Some(s);
        }
    }
    let mut persistent = vec![false; nodes.len()];
    for (src, _, _) in &roots {
        if let Src::Buf(id) = src {
            persistent[*id] = true;
        }
    }

    let mut slot_of = vec![usize::MAX; nodes.len()];
    let mut slot_caps: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut outbuf = Vec::new();
    for s in 0..steps.len() {
        // acquire ALL output slots BEFORE releasing this step's operands:
        // an output can therefore never alias a live (or same-step) operand
        step_outs(&steps[s], &mut outbuf);
        for &out_id in &outbuf {
            let need = numel(&nodes[out_id].dims);
            let slot = match free.iter().position(|&f| slot_caps[f] == need) {
                Some(p) if reuse => free.swap_remove(p),
                _ => {
                    slot_caps.push(need);
                    slot_caps.len() - 1
                }
            };
            slot_of[out_id] = slot;
        }
        if reuse {
            step_inputs(&steps[s], &mut scratch);
            for &id in &scratch {
                if last_use[id] == Some(s) && !persistent[id] {
                    let sl = slot_of[id];
                    if sl != usize::MAX && !free.contains(&sl) {
                        free.push(sl);
                    }
                }
            }
        }
    }

    // rewrite node ids -> slot ids
    for step in steps.iter_mut() {
        match step {
            Step::Fused { expr, out, .. } => {
                rewrite_expr(expr, &slot_of)?;
                *out = slot_of[*out];
            }
            Step::Gather { src, out, .. } => {
                rewrite_src(src, &slot_of)?;
                *out = slot_of[*out];
            }
            Step::Reduce { src, init, out, .. }
            | Step::Scan { src, init, out, .. }
            | Step::ReduceWindow { src, init, out, .. } => {
                rewrite_src(src, &slot_of)?;
                rewrite_src(init, &slot_of)?;
                *out = slot_of[*out];
            }
            Step::Dot { lhs, rhs, out, .. } => {
                rewrite_src(lhs, &slot_of)?;
                rewrite_src(rhs, &slot_of)?;
                *out = slot_of[*out];
            }
            Step::DynamicSlice { src, starts, out, .. } => {
                rewrite_src(src, &slot_of)?;
                for s in starts.iter_mut() {
                    rewrite_src(s, &slot_of)?;
                }
                *out = slot_of[*out];
            }
            Step::While { state, outs, .. } => {
                // the nested cond/body plans are self-contained (their own
                // slots); only this level's state sources and output ids
                // are rewritten
                for s in state.iter_mut() {
                    rewrite_src(s, &slot_of)?;
                }
                for o in outs.iter_mut() {
                    *o = slot_of[*o];
                }
            }
        }
    }
    let mut root_srcs = Vec::with_capacity(roots.len());
    for (mut src, dims, dt) in roots {
        rewrite_src(&mut src, &slot_of)?;
        root_srcs.push((src, dims, dt));
    }
    Ok((steps, slot_caps, root_srcs))
}

/// Level-schedule the (slot-rewritten) steps into waves. A step depends on
/// the last writer of every slot it reads (RAW) and — because the arena
/// recycles slots — on the last writer (WAW) and every reader since that
/// write (WAR) of every slot it writes. A step's wave is one past the
/// deepest wave it depends on, so steps sharing a wave are mutually
/// independent and may run in any order or concurrently.
fn build_waves(steps: &[Step], nslots: usize) -> Vec<Wave> {
    if steps.is_empty() {
        return Vec::new();
    }
    let mut wave_of = vec![0usize; steps.len()];
    let mut last_writer: Vec<Option<usize>> = vec![None; nslots];
    let mut readers: Vec<Vec<usize>> = vec![Vec::new(); nslots];
    let mut ins = Vec::new();
    let mut outs = Vec::new();
    let mut deepest = 0usize;
    for (s, step) in steps.iter().enumerate() {
        let mut w = 0usize;
        step_inputs(step, &mut ins);
        for &slot in &ins {
            if let Some(lw) = last_writer[slot] {
                w = w.max(wave_of[lw] + 1);
            }
        }
        step_outs(step, &mut outs);
        for &slot in &outs {
            if let Some(lw) = last_writer[slot] {
                w = w.max(wave_of[lw] + 1);
            }
            for &r in &readers[slot] {
                w = w.max(wave_of[r] + 1);
            }
        }
        wave_of[s] = w;
        deepest = deepest.max(w);
        for &slot in &ins {
            readers[slot].push(s);
        }
        for &slot in &outs {
            last_writer[slot] = Some(s);
            readers[slot].clear();
        }
    }
    let mut waves: Vec<Wave> =
        (0..=deepest).map(|_| Wave { steps: Vec::new(), whiles: Vec::new() }).collect();
    for (s, step) in steps.iter().enumerate() {
        if matches!(step, Step::While { .. }) {
            waves[wave_of[s]].whiles.push(s);
        } else {
            waves[wave_of[s]].steps.push(s);
        }
    }
    waves
}

// -------------------------------------------------------------- execution

/// Fused chunks stay L1-resident: each op in a fused expression streams
/// over at most this many elements before the next op reuses them.
const CHUNK: usize = 4096;

#[derive(Clone, Copy)]
struct Ctx<'a> {
    inputs: &'a [&'a Tensor],
    consts: &'a [Tensor],
    slots: &'a [Vec<f32>],
}

impl<'a> Ctx<'a> {
    fn slice(&self, s: &Src) -> &'a [f32] {
        match *s {
            Src::Input(i) => self.inputs[i].data.as_slice(),
            Src::Const(k) => self.consts[k].data.as_slice(),
            Src::Buf(b) => self.slots[b].as_slice(),
        }
    }
}

fn take_pooled(pool: &mut Vec<Vec<f32>>, len: usize) -> Vec<f32> {
    let mut v = pool.pop().unwrap_or_default();
    v.clear();
    v.resize(len, 0.0);
    v
}

/// Evaluate a fused expression over `out.len()` elements starting at flat
/// offset `start`, writing into `out`.
fn eval_fused(e: &FExpr, ctx: &Ctx, start: usize, out: &mut [f32], pool: &mut Vec<Vec<f32>>) {
    let len = out.len();
    match e {
        FExpr::Leaf(s) => out.copy_from_slice(&ctx.slice(s)[start..start + len]),
        FExpr::Splat(v) => kernels::fill(out, *v),
        FExpr::SplatLeaf(s) => kernels::fill(out, ctx.slice(s)[0]),
        FExpr::Un(op, a) => {
            eval_fused(a, ctx, start, out, pool);
            kernels::unary_inplace(out, *op);
        }
        FExpr::Bin(op, a, b) => match (a.as_ref(), b.as_ref()) {
            (_, FExpr::Splat(v)) => {
                eval_fused(a, ctx, start, out, pool);
                kernels::scalar_rhs_inplace(out, *v, *op);
            }
            (FExpr::Splat(v), _) => {
                eval_fused(b, ctx, start, out, pool);
                kernels::scalar_lhs_inplace(*v, out, *op);
            }
            _ => {
                eval_fused(a, ctx, start, out, pool);
                let mut t = take_pooled(pool, len);
                eval_fused(b, ctx, start, &mut t, pool);
                kernels::binary_inplace(out, &t, *op);
                pool.push(t);
            }
        },
        FExpr::Cmp(op, a, b) => {
            eval_fused(a, ctx, start, out, pool);
            let mut t = take_pooled(pool, len);
            eval_fused(b, ctx, start, &mut t, pool);
            kernels::compare_inplace(out, &t, *op);
            pool.push(t);
        }
        FExpr::Sel(c, a, b) => {
            eval_fused(a, ctx, start, out, pool);
            let mut tc = take_pooled(pool, len);
            eval_fused(c, ctx, start, &mut tc, pool);
            let mut tb = take_pooled(pool, len);
            eval_fused(b, ctx, start, &mut tb, pool);
            kernels::select_if_zero(out, &tc, &tb);
            pool.push(tb);
            pool.push(tc);
        }
    }
}

impl ExecutablePlan {
    /// Execute on the given inputs with a fresh scratch arena.
    pub fn execute(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, String> {
        let mut scratch = PlanScratch::default();
        self.execute_with_scratch(inputs, &mut scratch)
    }

    /// Execute, reusing `scratch` buffers across calls: the arena slots and
    /// the fused-chunk / dot-gather pools persist, so repeat runs of the
    /// same plan skip all per-step buffer allocation. (Transient
    /// allocations remain on cold paths — the `f64` accumulator of a
    /// non-suffix sum/product reduce, reduce-window's per-rank cursor,
    /// and `while` steps, whose per-iteration carried state is
    /// materialized as owned tensors even though the nested condition/
    /// body arenas are recycled.)
    pub fn execute_with_scratch(
        &self,
        inputs: &[&Tensor],
        scratch: &mut PlanScratch,
    ) -> Result<Vec<Tensor>, String> {
        if inputs.len() != self.param_dims.len() {
            return Err(format!(
                "plan takes {} parameters, got {} inputs",
                self.param_dims.len(),
                inputs.len()
            ));
        }
        for (pi, t) in inputs.iter().enumerate() {
            if t.shape != self.param_dims[pi] {
                return Err(format!(
                    "parameter {pi} expects shape {:?}, got input shape {:?}",
                    self.param_dims[pi], t.shape
                ));
            }
        }
        if scratch.slots.len() != self.slot_caps.len()
            || scratch.slots.iter().zip(&self.slot_caps).any(|(s, &c)| s.len() != c)
        {
            scratch.slots = self.slot_caps.iter().map(|&c| vec![0.0f32; c]).collect();
        }
        let PlanScratch { slots, pool, big, whiles } = scratch;
        if self.parallel && crate::util::pool::current_parallelism() > 1 {
            for wave in &self.waves {
                self.run_wave(wave, inputs, slots, pool, big, whiles)?;
            }
        } else {
            for step in &self.steps {
                self.run_step(step, inputs, slots, pool, big, whiles)?;
            }
        }
        let ctx = Ctx { inputs, consts: &self.consts, slots: slots.as_slice() };
        let mut outs = Vec::with_capacity(self.roots.len());
        for (src, dims, dt) in &self.roots {
            let n = numel(dims);
            let data = ctx.slice(src)[..n].to_vec();
            outs.push(Tensor::new(dims.clone(), *dt, data));
        }
        Ok(outs)
    }

    fn run_step(
        &self,
        step: &Step,
        inputs: &[&Tensor],
        slots: &mut Vec<Vec<f32>>,
        pool: &mut Vec<Vec<f32>>,
        big: &mut Vec<Vec<f32>>,
        whiles: &mut Vec<WhileScratch>,
    ) -> Result<(), String> {
        if let Step::While { cond, body, state, outs, elem_dims, elem_dtypes, scratch_idx } = step
        {
            while whiles.len() <= *scratch_idx {
                whiles.push(WhileScratch::default());
            }
            // copy the initial state out of the arena into owned tensors
            let mut st: Vec<Tensor> = Vec::with_capacity(state.len());
            {
                let ctx = Ctx { inputs, consts: &self.consts, slots: slots.as_slice() };
                for (k, src) in state.iter().enumerate() {
                    let n = numel(&elem_dims[k]);
                    st.push(Tensor::new(
                        elem_dims[k].clone(),
                        elem_dtypes[k],
                        ctx.slice(src)[..n].to_vec(),
                    ));
                }
            }
            let ws = &mut whiles[*scratch_idx];
            let mut iters = 0usize;
            loop {
                let refs: Vec<&Tensor> = st.iter().collect();
                let c = cond.execute_with_scratch(&refs, &mut ws.cond)?;
                if c.len() != 1 || c[0].numel() != 1 {
                    return Err("while condition did not produce a scalar".to_string());
                }
                if c[0].data[0] == 0.0 {
                    break;
                }
                st = body.execute_with_scratch(&refs, &mut ws.body)?;
                iters += 1;
                if iters >= MAX_WHILE_ITERS {
                    return Err(format!("exceeded {MAX_WHILE_ITERS} while iterations"));
                }
            }
            for (k, &o) in outs.iter().enumerate() {
                let n = numel(&elem_dims[k]);
                slots[o][..n].copy_from_slice(&st[k].data[..n]);
            }
            return Ok(());
        }
        let out_idx = step_single_out(step);
        let mut out = std::mem::take(&mut slots[out_idx]);
        let res = self.compute_step(step, inputs, slots.as_slice(), &mut out, pool, big);
        slots[out_idx] = out;
        res
    }

    /// Run one non-`While` step against an immutable view of the arena,
    /// writing into `out` (the step's taken output buffer). Factored out of
    /// [`Self::run_step`] so [`Self::run_wave`] can execute the steps of a
    /// wave concurrently against the same shared view, each task with its
    /// own temp pools.
    fn compute_step(
        &self,
        step: &Step,
        inputs: &[&Tensor],
        slots: &[Vec<f32>],
        out: &mut [f32],
        pool: &mut Vec<Vec<f32>>,
        big: &mut Vec<Vec<f32>>,
    ) -> Result<(), String> {
        {
            let ctx = Ctx { inputs, consts: &self.consts, slots };
            match step {
                Step::Fused { expr, n, .. } => {
                    let mut start = 0usize;
                    while start < *n {
                        let len = CHUNK.min(*n - start);
                        eval_fused(expr, &ctx, start, &mut out[start..start + len], pool);
                        start += len;
                    }
                }
                Step::Gather { src, spec, .. } => {
                    let s = ctx.slice(src);
                    kernels::gather_strided(
                        s,
                        &mut out[..spec.n],
                        &spec.out_dims,
                        &spec.ostr,
                        &spec.sstr,
                    );
                }
                Step::Reduce { src, init, comb, shape, .. } => {
                    let s = ctx.slice(src);
                    let iv = ctx.slice(init)[0];
                    run_reduce(s, iv, comb, shape, &mut out);
                }
                Step::Scan { src, init, comb, n, len, sstride, reverse, .. } => {
                    let s = ctx.slice(src);
                    let iv = ctx.slice(init)[0];
                    let o = &mut out[..*n];
                    for base in 0..*n {
                        if (base / sstride) % len != 0 {
                            continue;
                        }
                        let mut acc = iv;
                        if *reverse {
                            for j in (0..*len).rev() {
                                let p = base + j * sstride;
                                acc = comb_apply(comb, acc, s[p]);
                                o[p] = acc;
                            }
                        } else {
                            for j in 0..*len {
                                let p = base + j * sstride;
                                acc = comb_apply(comb, acc, s[p]);
                                o[p] = acc;
                            }
                        }
                    }
                }
                Step::ReduceWindow {
                    src,
                    init,
                    comb,
                    in_dims,
                    istr,
                    out_dims,
                    ostr,
                    wsize,
                    wstr,
                    wstride,
                    pad,
                    ..
                } => {
                    let s = ctx.slice(src);
                    let iv = ctx.slice(init)[0];
                    let rank = in_dims.len();
                    let win_n: usize = wsize.iter().product();
                    let out_n = numel(out_dims);
                    let mut starts = vec![0isize; rank];
                    for (oi, slot) in out[..out_n].iter_mut().enumerate() {
                        for d in 0..rank {
                            let idx = (oi / ostr[d]) % out_dims[d];
                            starts[d] = (idx * wstride[d]) as isize - pad[d].0 as isize;
                        }
                        let mut acc = iv;
                        'window: for wi in 0..win_n {
                            let mut li = 0usize;
                            for d in 0..rank {
                                let pos = starts[d] + ((wi / wstr[d]) % wsize[d]) as isize;
                                if pos < 0 || pos >= in_dims[d] as isize {
                                    continue 'window; // padding element: identity
                                }
                                li += pos as usize * istr[d];
                            }
                            acc = comb_apply(comb, acc, s[li]);
                        }
                        *slot = acc;
                    }
                }
                Step::Dot { lhs, lspec, rhs, rspec, b, m, k, n, .. } => {
                    let ls = ctx.slice(lhs);
                    let rs = ctx.slice(rhs);
                    let mut lt = take_pooled(big, lspec.n);
                    kernels::gather_strided(
                        ls,
                        &mut lt,
                        &lspec.out_dims,
                        &lspec.ostr,
                        &lspec.sstr,
                    );
                    let mut rt = take_pooled(big, rspec.n);
                    kernels::gather_strided(
                        rs,
                        &mut rt,
                        &rspec.out_dims,
                        &rspec.ostr,
                        &rspec.sstr,
                    );
                    let o = &mut out[..b * m * n];
                    kernels::fill(o, 0.0);
                    for bi in 0..*b {
                        kernels::matmul_acc(
                            &mut o[bi * m * n..(bi + 1) * m * n],
                            &lt[bi * m * k..(bi + 1) * m * k],
                            &rt[bi * k * n..(bi + 1) * k * n],
                            *m,
                            *k,
                            *n,
                        );
                    }
                    big.push(lt);
                    big.push(rt);
                }
                Step::DynamicSlice { src, starts, in_dims, istr, sizes, ostr, n, .. } => {
                    let s = ctx.slice(src);
                    let mut base = 0usize;
                    for d in 0..in_dims.len() {
                        let v = ctx.slice(&starts[d])[0];
                        // starts clamp into [0, dim - size], per HLO
                        // semantics (sizes[d] <= in_dims[d] is validated
                        // at compile time)
                        let max_start = (in_dims[d] - sizes[d]) as i64;
                        let start = (v as i64).clamp(0, max_start);
                        base += start as usize * istr[d];
                    }
                    kernels::gather_strided_offset(s, &mut out[..*n], sizes, ostr, istr, base);
                }
                Step::While { .. } => unreachable!("handled above"),
            }
        }
        Ok(())
    }

    /// Execute one wave: the wave's non-`While` steps concurrently on the
    /// worker pool, then its `While` steps serially (their nested plans own
    /// mutable per-step scratch). Every output buffer is taken from the
    /// arena up front, so the parallel batch runs against an immutable slot
    /// view; parallel tasks use fresh temp pools (the shared scratch pools
    /// are not thread-safe), a trade wave execution makes for concurrency.
    fn run_wave(
        &self,
        wave: &Wave,
        inputs: &[&Tensor],
        slots: &mut Vec<Vec<f32>>,
        pool: &mut Vec<Vec<f32>>,
        big: &mut Vec<Vec<f32>>,
        whiles: &mut Vec<WhileScratch>,
    ) -> Result<(), String> {
        if wave.steps.len() <= 1 {
            for &si in &wave.steps {
                self.run_step(&self.steps[si], inputs, slots, pool, big, whiles)?;
            }
        } else {
            let mut outs: Vec<Vec<f32>> = wave
                .steps
                .iter()
                .map(|&si| std::mem::take(&mut slots[step_single_out(&self.steps[si])]))
                .collect();
            let err: Mutex<Option<String>> = Mutex::new(None);
            {
                let view: &[Vec<f32>] = slots.as_slice();
                let obase = outs.as_mut_ptr() as usize;
                crate::util::pool::run_parts(wave.steps.len(), |i| {
                    // SAFETY: part i exclusively owns outs[i]; `outs` is not
                    // touched again until run_parts has joined every part
                    let out = unsafe { &mut *(obase as *mut Vec<f32>).add(i) };
                    let step = &self.steps[wave.steps[i]];
                    let (mut tpool, mut tbig) = (Vec::new(), Vec::new());
                    if let Err(e) =
                        self.compute_step(step, inputs, view, out, &mut tpool, &mut tbig)
                    {
                        let mut first = err.lock().unwrap();
                        if first.is_none() {
                            *first = Some(e);
                        }
                    }
                });
            }
            for (i, buf) in outs.into_iter().enumerate() {
                slots[step_single_out(&self.steps[wave.steps[i]])] = buf;
            }
            if let Some(e) = err.into_inner().unwrap() {
                return Err(e);
            }
        }
        for &si in &wave.whiles {
            self.run_step(&self.steps[si], inputs, slots, pool, big, whiles)?;
        }
        Ok(())
    }
}

fn run_reduce(s: &[f32], iv: f32, comb: &Combiner, shape: &ReduceShape, out: &mut [f32]) {
    match shape {
        ReduceShape::Rows { rows, cols } => {
            let o = &mut out[..*rows];
            match comb {
                Combiner::Add => kernels::reduce_rows_wide(s, *cols, iv, false, o),
                Combiner::Mul => kernels::reduce_rows_wide(s, *cols, iv, true, o),
                Combiner::Max => kernels::reduce_rows_fold(s, *cols, iv, BinOp::Max, o),
                Combiner::Min => kernels::reduce_rows_fold(s, *cols, iv, BinOp::Min, o),
                Combiner::Generic(se) => {
                    for (r, slot) in o.iter_mut().enumerate() {
                        let mut acc = iv;
                        for &v in &s[r * cols..(r + 1) * cols] {
                            acc = eval_sexpr(se, acc, v);
                        }
                        *slot = acc;
                    }
                }
            }
        }
        ReduceShape::Scatter { in_dims, istr, kept, out_n } => {
            let oi_of = |li: usize| {
                let mut oi = 0usize;
                for &(d, os) in kept {
                    oi += ((li / istr[d]) % in_dims[d]) * os;
                }
                oi
            };
            match comb {
                // sum/product accumulate in f64 (oracle grade, same as the
                // tree-walker: a reduce can span millions of elements)
                Combiner::Add | Combiner::Mul => {
                    let mul = matches!(comb, Combiner::Mul);
                    let mut acc = vec![iv as f64; *out_n];
                    for (li, &v) in s.iter().enumerate() {
                        let oi = oi_of(li);
                        if mul {
                            acc[oi] *= v as f64;
                        } else {
                            acc[oi] += v as f64;
                        }
                    }
                    for (o, a) in out[..*out_n].iter_mut().zip(&acc) {
                        *o = *a as f32;
                    }
                }
                _ => {
                    kernels::fill(&mut out[..*out_n], iv);
                    for (li, &v) in s.iter().enumerate() {
                        let oi = oi_of(li);
                        out[oi] = comb_apply(comb, out[oi], v);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::hlo::eval::evaluate;
    use crate::runtime::hlo::parser::parse_module;
    use crate::util::compare::allclose;

    fn t(data: &[f32]) -> Tensor {
        Tensor::from_vec(data.to_vec())
    }

    /// Run through both the tree-walker and the plan; assert agreement and
    /// return the plan outputs.
    fn run_both(text: &str, inputs: &[&Tensor]) -> Vec<Tensor> {
        let m = parse_module(text).unwrap();
        let want = evaluate(&m, inputs).unwrap();
        for opts in [
            PlanOptions { reuse_buffers: true, parallel: false },
            PlanOptions { reuse_buffers: false, parallel: false },
            PlanOptions { reuse_buffers: true, parallel: true },
        ] {
            let plan = ExecutablePlan::compile_with(&m, opts).unwrap();
            let got = plan.execute(inputs).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.shape, w.shape);
                assert!(allclose(g, w, 0.0, 0.0), "arena={}: {:?} vs {:?}", opts.reuse_buffers, g.data, w.data);
            }
        }
        let plan = ExecutablePlan::compile(&m).unwrap();
        plan.execute(inputs).unwrap()
    }

    #[test]
    fn relu_like_chain_fuses_to_one_step() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[8]{0} parameter(0)\n  z = f32[] constant(0)\n  zb = f32[8]{0} broadcast(z), dimensions={}\n  ROOT r = f32[8]{0} maximum(x, zb)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        assert_eq!(plan.step_count(), 1, "broadcast + maximum should fuse");
        assert_eq!(plan.slot_count(), 1);
        let x = t(&[-2.0, -1.0, 0.0, 1.0, 2.0, -0.5, 0.5, 3.0]);
        let out = plan.execute(&[&x]).unwrap();
        assert_eq!(out[0].data, vec![0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.5, 3.0]);
        run_both(text, &[&x]);
    }

    #[test]
    fn independent_steps_share_a_wave_and_run_in_parallel() {
        let text = "HloModule t\n\nradd {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[4,8]{1,0} parameter(0)\n  y = f32[4,8]{1,0} parameter(1)\n  z = f32[] constant(0)\n  sx = f32[4]{0} reduce(x, z), dimensions={1}, to_apply=radd\n  sy = f32[4]{0} reduce(y, z), dimensions={1}, to_apply=radd\n  ROOT r = f32[4]{0} add(sx, sy)\n}\n";
        let m = parse_module(text).unwrap();
        let opts = PlanOptions { reuse_buffers: true, parallel: true };
        let plan = ExecutablePlan::compile_with(&m, opts).unwrap();
        assert_eq!(plan.step_count(), 3);
        assert_eq!(plan.wave_count(), 2, "independent reduces share a wave; add waits");
        let x = Tensor::new(vec![4, 8], DType::F32, (0..32).map(|i| i as f32).collect());
        let y = Tensor::new(vec![4, 8], DType::F32, (0..32).map(|i| (31 - i) as f32).collect());
        let serial = ExecutablePlan::compile(&m).unwrap().execute(&[&x, &y]).unwrap();
        // force a multi-thread pool so the wave path actually runs
        let pool = crate::util::pool::WorkerPool::new(4);
        let par = pool.install(|| plan.execute(&[&x, &y]).unwrap());
        assert_eq!(par.len(), serial.len());
        for (p, s) in par.iter().zip(&serial) {
            assert_eq!(p.data, s.data, "wave execution must be bit-identical");
        }
    }

    #[test]
    fn softmax_module_matches_tree_walker() {
        let text = "HloModule t\n\nrmax {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT m = f32[] maximum(a, b)\n}\n\nradd {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[4,8]{1,0} parameter(0)\n  ninf = f32[] constant(-inf)\n  mx = f32[4]{0} reduce(x, ninf), dimensions={1}, to_apply=rmax\n  mb = f32[4,8]{1,0} broadcast(mx), dimensions={0}\n  sh = f32[4,8]{1,0} subtract(x, mb)\n  ex = f32[4,8]{1,0} exponential(sh)\n  z = f32[] constant(0)\n  sm = f32[4]{0} reduce(ex, z), dimensions={1}, to_apply=radd\n  sb = f32[4,8]{1,0} broadcast(sm), dimensions={0}\n  ROOT y = f32[4,8]{1,0} divide(ex, sb)\n}\n";
        let x = Tensor::new(
            vec![4, 8],
            DType::F32,
            (0..32).map(|i| ((i * 7 % 13) as f32) - 6.0).collect(),
        );
        let out = run_both(text, &[&x]);
        // rows sum to 1
        for r in 0..4 {
            let s: f32 = out[0].data[r * 8..(r + 1) * 8].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn call_select_compare_chain_is_inlined() {
        // leaky-relu via call, as jnp.where lowers
        let text = "HloModule t\n\n_where.1 {\n  p = pred[4]{0} parameter(0)\n  a = f32[4]{0} parameter(1)\n  b = f32[4]{0} parameter(2)\n  ROOT s = f32[4]{0} select(p, a, b)\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  zero = f32[] constant(0)\n  zb = f32[4]{0} broadcast(zero), dimensions={}\n  c = pred[4]{0} compare(x, zb), direction=GE\n  tenth = f32[] constant(0.1)\n  tb = f32[4]{0} broadcast(tenth), dimensions={}\n  lo = f32[4]{0} multiply(x, tb)\n  ROOT w = f32[4]{0} call(c, x, lo), to_apply=_where.1\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        // x has three consumers, so it stays an input; everything else
        // fuses into the one select expression
        assert_eq!(plan.step_count(), 1, "call body should inline and fuse");
        let x = t(&[-2.0, -0.5, 0.0, 3.0]);
        let out = run_both(text, &[&x]);
        assert!(allclose(&out[0], &t(&[-0.2, -0.05, 0.0, 3.0]), 1e-6, 1e-7));
    }

    #[test]
    fn transpose_and_dot_match_tree_walker() {
        let text = "HloModule t\n\nENTRY e {\n  a = f32[2,3]{1,0} parameter(0)\n  b = f32[3,2]{1,0} parameter(1)\n  at = f32[3,2]{1,0} transpose(a), dimensions={1,0}\n  s = f32[3,2]{1,0} add(at, b)\n  d = f32[2,2]{1,0} dot(a, s), lhs_contracting_dims={1}, rhs_contracting_dims={0}\n  ROOT o = (f32[3,2], f32[2,2]) tuple(s, d)\n}\n";
        let a = Tensor::new(vec![2, 3], DType::F32, vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], DType::F32, vec![7., 8., 9., 10., 11., 12.]);
        run_both(text, &[&a, &b]);
    }

    #[test]
    fn cumsum_scan_and_generic_window_match() {
        let scan = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[2,4]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT w = f32[2,4]{1,0} reduce-window(x, z), window={size=1x4 pad=0_0x3_0}, to_apply=r\n}\n";
        let x = Tensor::new(vec![2, 4], DType::F32, vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let out = run_both(scan, &[&x]);
        assert_eq!(out[0].data, vec![1., 3., 6., 10., 10., 30., 60., 100.]);

        // reverse scan (pad on the high side): output i sees elements i..
        let rev = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[2,4]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT w = f32[2,4]{1,0} reduce-window(x, z), window={size=1x4 pad=0_0x0_3}, to_apply=r\n}\n";
        let out = run_both(rev, &[&x]);
        assert_eq!(out[0].data, vec![10., 9., 7., 4., 100., 90., 70., 40.]);

        let win = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] maximum(a, b)\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  z = f32[] constant(-inf)\n  ROOT w = f32[3]{0} reduce-window(x, z), window={size=2}, to_apply=r\n}\n";
        let x = t(&[1., 5., 2., 4.]);
        let out = run_both(win, &[&x]);
        assert_eq!(out[0].data, vec![5., 5., 4.]);
    }

    #[test]
    fn generic_combiner_compiles_to_scalar_expr() {
        // combiner a + 2*b: not a recognized monoid
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  c = f32[] constant(2)\n  s = f32[] multiply(b, c)\n  ROOT o = f32[] add(a, s)\n}\n\nENTRY e {\n  x = f32[3]{0} parameter(0)\n  z = f32[] constant(0)\n  ROOT red = f32[]{} reduce(x, z), dimensions={0}, to_apply=r\n}\n";
        let out = run_both(text, &[&t(&[1.0, 2.0, 3.0])]);
        assert_eq!(out[0].data, vec![12.0]);
    }

    #[test]
    fn non_suffix_reduce_takes_scatter_path() {
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[2,3]{1,0} parameter(0)\n  z = f32[] constant(0)\n  ROOT red = f32[3]{0} reduce(x, z), dimensions={0}, to_apply=r\n}\n";
        let x = Tensor::new(vec![2, 3], DType::F32, vec![1., 5., 2., -1., 0., 4.]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].data, vec![0.0, 5.0, 6.0]);
    }

    #[test]
    fn multi_output_tuple_with_shared_intermediates() {
        // adam-shaped: intermediates are both outputs and operands
        let text = "HloModule t\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  a = f32[4]{0} add(x, x)\n  b = f32[4]{0} multiply(a, x)\n  ROOT o = (f32[4], f32[4]) tuple(a, b)\n}\n";
        let x = t(&[1., 2., 3., 4.]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].data, vec![2., 4., 6., 8.]);
        assert_eq!(out[1].data, vec![2., 8., 18., 32.]);
    }

    #[test]
    fn arena_never_aliases_a_live_operand() {
        // `a` is materialized early (two consumers) and stays live across
        // many short-lived buffers that churn the free list; its slot must
        // never be recycled while live, or z1/z2 read garbage
        let text = "HloModule t\n\nENTRY e {\n  x = f32[64]{0} parameter(0)\n  a = f32[64]{0} negate(x)\n  b = f32[64]{0} exponential(x)\n  c = f32[64]{0} add(b, b)\n  d = f32[64]{0} multiply(c, c)\n  g = f32[64]{0} maximum(d, d)\n  h = f32[64]{0} minimum(g, g)\n  z1 = f32[64]{0} add(a, h)\n  z2 = f32[64]{0} multiply(a, h)\n  ROOT o = (f32[64], f32[64]) tuple(z1, z2)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        // recycling must actually happen for the test to mean anything
        assert!(
            plan.slot_count() < plan.step_count(),
            "expected the arena to recycle buffers ({} slots / {} steps)",
            plan.slot_count(),
            plan.step_count()
        );
        let x = Tensor::from_vec((0..64).map(|i| (i as f32) * 0.1 - 3.2).collect());
        run_both(text, &[&x]);
    }

    #[test]
    fn scalar_output_and_dead_code() {
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[6]{0} parameter(0)\n  dead = f32[6]{0} exponential(x)\n  z = f32[] constant(0)\n  s = f32[] reduce(x, z), dimensions={0}, to_apply=r\n  c = f32[] constant(6)\n  mean = f32[] divide(s, c)\n  ROOT r1 = f32[1]{0} reshape(mean)\n}\n";
        let x = t(&[1., 2., 3., 4., 5., 6.]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].shape, vec![1]);
        assert!((out[0].data[0] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn dead_code_elimination_is_transitive() {
        // dead1 is consumed only by the dead reduce: BOTH must be dropped,
        // including the materializing reduce step, leaving only the live
        // negate — one fused step
        let text = "HloModule t\n\nr {\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT s = f32[] add(a, b)\n}\n\nENTRY e {\n  x = f32[6]{0} parameter(0)\n  dead1 = f32[6]{0} exponential(x)\n  z = f32[] constant(0)\n  dead2 = f32[] reduce(dead1, z), dimensions={0}, to_apply=r\n  ROOT y = f32[6]{0} negate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        assert_eq!(plan.step_count(), 1, "dead reduce chain must not be compiled");
        let x = t(&[1., 2., 3., 4., 5., 6.]);
        run_both(text, &[&x]);
    }

    #[test]
    fn input_validation_matches_oracle_contract() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT n = f32[2]{0} negate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        assert!(plan.execute(&[]).is_err());
        let wrong = t(&[1.0, 2.0, 3.0]);
        let e = plan.execute(&[&wrong]).unwrap_err();
        assert!(e.contains("expects shape"), "{e}");
    }

    #[test]
    fn unsupported_opcode_fails_at_compile_time() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT y = f32[2]{0} frobnicate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let e = ExecutablePlan::compile(&m).unwrap_err();
        assert!(e.contains("frobnicate"), "{e}");
    }

    #[test]
    fn root_can_be_a_parameter_or_constant() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[3]{0} parameter(0)\n  ROOT o = (f32[3], f32[3]) tuple(x, x)\n}\n";
        let x = t(&[1., 2., 3.]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].data, out[1].data);

        let text = "HloModule t\n\nENTRY e {\n  ROOT c = f32[2,2]{1,0} constant({ {1, 2}, {3, 4} })\n}\n";
        let out = run_both(text, &[]);
        assert_eq!(out[0].data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn iota_folds_into_a_compile_time_constant() {
        let text = "HloModule t\n\nENTRY e {\n  i = s32[2,3]{1,0} iota(), iota_dimension=1\n  x = f32[2,3]{1,0} parameter(0)\n  ic = f32[2,3]{1,0} convert(i)\n  ROOT s = f32[2,3]{1,0} add(x, ic)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        // iota is a const; convert(int->float) is identity; the add fuses:
        // a single step
        assert_eq!(plan.step_count(), 1, "iota + convert + add should be one fused step");
        let x = Tensor::new(vec![2, 3], DType::F32, vec![10., 20., 30., 40., 50., 60.]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].data, vec![10., 21., 32., 40., 51., 62.]);
    }

    #[test]
    fn dynamic_slice_with_runtime_starts_matches_evaluator() {
        // start index computed from data (trunc of x[0,0]), then clamped
        let text = "HloModule t\n\nENTRY e {\n  x = f32[3,4]{1,0} parameter(0)\n  i = s32[] parameter(1)\n  z = s32[] constant(0)\n  ROOT d = f32[2,4]{1,0} dynamic-slice(x, i, z), dynamic_slice_sizes={2,4}\n}\n";
        let x = Tensor::new(vec![3, 4], DType::F32, (0..12).map(|v| v as f32).collect());
        for start in [-3.0f32, 0.0, 1.0, 7.0] {
            let i = Tensor::new(vec![], DType::I32, vec![start]);
            let out = run_both(text, &[&x, &i]);
            let s = (start as i64).clamp(0, 1) as usize;
            assert_eq!(out[0].data, x.data[s * 4..s * 4 + 8].to_vec(), "start {start}");
        }
    }

    #[test]
    fn while_loop_matches_evaluator_and_reuses_scratch() {
        // newton-sqrt shaped: state (i, y, x), body refines y, 8 iters
        let text = "HloModule t\n\nbody {\n  p = (s32[], f32[8]{0}, f32[8]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  y = f32[8]{0} get-tuple-element(p), index=1\n  x = f32[8]{0} get-tuple-element(p), index=2\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  q = f32[8]{0} divide(x, y)\n  s = f32[8]{0} add(y, q)\n  h = f32[] constant(0.5)\n  hb = f32[8]{0} broadcast(h), dimensions={}\n  y2 = f32[8]{0} multiply(s, hb)\n  ROOT t = (s32[], f32[8]{0}, f32[8]{0}) tuple(i2, y2, x)\n}\n\ncond {\n  p = (s32[], f32[8]{0}, f32[8]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  n = s32[] constant(8)\n  ROOT c = pred[] compare(i, n), direction=LT\n}\n\nENTRY e {\n  x = f32[8]{0} parameter(0)\n  one = f32[] constant(1)\n  y0 = f32[8]{0} broadcast(one), dimensions={}\n  z = s32[] constant(0)\n  st = (s32[], f32[8]{0}, f32[8]{0}) tuple(z, y0, x)\n  w = (s32[], f32[8]{0}, f32[8]{0}) while(st), condition=cond, body=body\n  ROOT y = f32[8]{0} get-tuple-element(w), index=1\n}\n";
        let x = Tensor::from_vec(vec![4.0, 9.0, 16.0, 25.0, 2.0, 0.25, 1.0, 100.0]);
        let out = run_both(text, &[&x]);
        for (got, want) in out[0].data.iter().zip(x.data.iter().map(|v| v.sqrt())) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
        // scratch reuse across runs is stable (nested while scratches too)
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        let mut scratch = PlanScratch::default();
        let a = plan.execute_with_scratch(&[&x], &mut scratch).unwrap();
        let b = plan.execute_with_scratch(&[&x], &mut scratch).unwrap();
        assert_eq!(a[0].data, b[0].data);
    }

    #[test]
    fn dead_while_is_dropped_entirely() {
        let text = "HloModule t\n\nbody {\n  p = (s32[]) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  ROOT t = (s32[]) tuple(i2)\n}\n\ncond {\n  p = (s32[]) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  n = s32[] constant(3)\n  ROOT c = pred[] compare(i, n), direction=LT\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  z = s32[] constant(0)\n  st = (s32[]) tuple(z)\n  w = (s32[]) while(st), condition=cond, body=body\n  dead = s32[] get-tuple-element(w), index=0\n  ROOT y = f32[4]{0} negate(x)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        assert_eq!(plan.step_count(), 1, "unused while must be dead-code eliminated");
        run_both(text, &[&t(&[1., 2., 3., 4.])]);
    }

    #[test]
    fn partially_used_while_keeps_all_state_elements() {
        // only element 1 of the state is consumed; the loop still runs
        let text = "HloModule t\n\nbody {\n  p = (s32[], f32[4]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  x = f32[4]{0} get-tuple-element(p), index=1\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  x2 = f32[4]{0} add(x, x)\n  ROOT t = (s32[], f32[4]{0}) tuple(i2, x2)\n}\n\ncond {\n  p = (s32[], f32[4]{0}) parameter(0)\n  i = s32[] get-tuple-element(p), index=0\n  n = s32[] constant(2)\n  ROOT c = pred[] compare(i, n), direction=LT\n}\n\nENTRY e {\n  x = f32[4]{0} parameter(0)\n  z = s32[] constant(0)\n  st = (s32[], f32[4]{0}) tuple(z, x)\n  w = (s32[], f32[4]{0}) while(st), condition=cond, body=body\n  ROOT y = f32[4]{0} get-tuple-element(w), index=1\n}\n";
        let out = run_both(text, &[&t(&[1., -2., 3., 0.5])]);
        assert_eq!(out[0].data, vec![4., -8., 12., 2.]);
    }

    #[test]
    fn convert_chain_fuses_and_matches_evaluator() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[6]{0} parameter(0)\n  i = s32[6]{0} convert(x)\n  b = f32[6]{0} convert(i)\n  p = pred[6]{0} convert(b)\n  ROOT o = (s32[6], f32[6], pred[6]) tuple(i, b, p)\n}\n";
        let x = t(&[2.9, -1.1, 0.0, 0.4, -0.6, 7.0]);
        let out = run_both(text, &[&x]);
        assert_eq!(out[0].data, vec![2.0, -1.0, 0.0, 0.0, -0.0, 7.0]);
        assert_eq!(out[1].data, out[0].data);
        assert_eq!(out[2].data, vec![1.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(out[0].dtype, DType::I32);
        assert_eq!(out[2].dtype, DType::Bool);
    }

    #[test]
    fn scratch_reuse_is_stable_across_runs() {
        let text = "HloModule t\n\nENTRY e {\n  x = f32[512]{0} parameter(0)\n  e1 = f32[512]{0} exponential(x)\n  ROOT s = f32[512]{0} multiply(e1, x)\n}\n";
        let m = parse_module(text).unwrap();
        let plan = ExecutablePlan::compile(&m).unwrap();
        let mut scratch = PlanScratch::default();
        let x1 = Tensor::from_vec((0..512).map(|i| (i as f32) / 512.0).collect());
        let x2 = Tensor::from_vec((0..512).map(|i| -(i as f32) / 256.0).collect());
        let a1 = plan.execute_with_scratch(&[&x1], &mut scratch).unwrap();
        let b = plan.execute_with_scratch(&[&x2], &mut scratch).unwrap();
        let a2 = plan.execute_with_scratch(&[&x1], &mut scratch).unwrap();
        assert_eq!(a1[0].data, a2[0].data);
        assert!(allclose(&b[0], &evaluate(&m, &[&x2]).unwrap()[0], 0.0, 0.0));
    }
}
