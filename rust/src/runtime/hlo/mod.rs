//! Self-contained HLO-text interpreter: lexer, parser, and evaluator for
//! the text format `python/compile/aot.py` emits. This replaces the
//! PJRT/XLA native runtime the crate previously linked against — the
//! golden-oracle path now builds and runs hermetically (no external
//! crates, no native libraries, no network), which is what lets plain
//! `cargo test` execute the checked-in `artifacts/*.hlo.txt` fixtures on
//! every platform.
//!
//! Layering:
//! * [`lexer`] — per-line tokenization (the printer emits one instruction
//!   per line);
//! * [`parser`] — [`parser::Module`] / [`parser::Computation`] /
//!   [`parser::Instr`] with operands resolved to indices at parse time;
//! * [`plan`] — compiles a module once into an [`plan::ExecutablePlan`]
//!   (call inlining, elementwise fusion, combiner resolution, buffer
//!   arena) that executes many times; this is the production oracle path;
//! * [`eval`] — the reference tree-walking evaluator, kept as the
//!   differential-testing baseline and as a fallback for modules outside
//!   the plan compiler's op set.

pub mod eval;
pub mod lexer;
pub mod parser;
pub mod plan;

pub use eval::evaluate;
pub use parser::{parse_module, Module, ParseError};
pub use plan::{ExecutablePlan, PlanOptions, PlanScratch};
