//! Self-contained HLO-text interpreter: lexer, parser, and evaluator for
//! the text format `python/compile/aot.py` emits. This replaces the
//! PJRT/XLA native runtime the crate previously linked against — the
//! golden-oracle path now builds and runs hermetically (no external
//! crates, no native libraries, no network), which is what lets plain
//! `cargo test` execute the checked-in `artifacts/*.hlo.txt` fixtures on
//! every platform.
//!
//! Layering:
//! * [`lexer`] — per-line tokenization (the printer emits one instruction
//!   per line);
//! * [`parser`] — [`parser::Module`] / [`parser::Computation`] /
//!   [`parser::Instr`] with operands resolved to indices at parse time;
//! * [`plan`] — compiles a module once into an [`plan::ExecutablePlan`]
//!   (call inlining, elementwise fusion, combiner resolution, buffer
//!   arena) that executes many times; this is the production oracle path;
//! * [`eval`] — the reference tree-walking evaluator, kept as the
//!   differential-testing baseline and as a fallback for modules outside
//!   the plan compiler's op set.

pub mod eval;
pub mod lexer;
pub mod parser;
pub mod plan;

pub use eval::evaluate;
pub use parser::{parse_module, Module, ParseError};
pub use plan::{ExecutablePlan, PlanOptions, PlanScratch};

use crate::util::kernels::UnaryOp;
use parser::ElemType;

/// Iteration cap for `while` loops (shared by the evaluator and the plan
/// executor): a malformed module whose condition never flips must fail
/// with an error, not hang the worker pool.
pub(crate) const MAX_WHILE_ITERS: usize = 1_000_000;

/// The numeric effect of an HLO `convert` from `src` to `dst`, as a shared
/// scalar op (`None` = identity). Host data stays `f32`; what is modeled:
///
/// * to an integer type — truncation toward zero ([`UnaryOp::Trunc`]);
/// * to `pred` — `x != 0` as 0.0/1.0 ([`UnaryOp::NonZero`]);
/// * to `f16` / `bf16` — round-to-nearest-even quantization;
/// * to `f32` / `f64`, or between integer widths — identity (integer
///   values are stored as exact small floats, so width changes are
///   value-preserving in this model).
///
/// One table serves both the plan compiler and the tree-walking evaluator,
/// so the two stay bit-identical by construction.
pub(crate) fn convert_op(src: ElemType, dst: ElemType) -> Option<UnaryOp> {
    match dst {
        ElemType::Pred => {
            if src == ElemType::Pred {
                None
            } else {
                Some(UnaryOp::NonZero)
            }
        }
        _ if dst.is_int() => {
            if src.is_int() || src == ElemType::Pred {
                None
            } else {
                Some(UnaryOp::Trunc)
            }
        }
        ElemType::F16 => Some(UnaryOp::F16Round),
        ElemType::Bf16 => Some(UnaryOp::Bf16Round),
        _ => None,
    }
}
