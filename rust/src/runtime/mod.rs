//! PJRT runtime: loads AOT-compiled JAX reference computations (HLO text in
//! `artifacts/*.hlo.txt`) and executes them on the XLA CPU client. This is
//! the L2 golden oracle — an *independent* numerical reference produced by
//! the JAX/Pallas build path, cross-checked against the Rust references and
//! used for Pass@1 verification of the showcase kernels.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::util::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A loaded, compiled golden computation.
pub struct GoldenOracle {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

thread_local! {
    // PjRtClient is Rc-backed (not Send); keep one per thread. Oracle use
    // is confined to the main thread in practice (CLI, tests, benches).
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// Run `f` with the thread's lazily-created CPU client.
fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?);
        }
        f(slot.as_ref().unwrap())
    })
}

impl GoldenOracle {
    /// Load an HLO text artifact and compile it.
    pub fn load(path: &Path) -> Result<GoldenOracle> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|c| {
            c.compile(&comp).with_context(|| format!("compiling {path:?}"))
        })?;
        Ok(GoldenOracle {
            exe,
            name: path.file_stem().and_then(|s| s.to_str()).unwrap_or("oracle").to_string(),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 tensor inputs; returns the tuple of outputs.
    /// (aot.py lowers with `return_tuple=True`.)
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let shape: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&shape)
                    .map_err(|e| anyhow!("reshape literal: {e}"))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        let out = result[0][0].to_literal_sync().map_err(|e| anyhow!("sync: {e}"))?;
        let tuple = out.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        tuple
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
                Ok(Tensor::new(if dims.is_empty() { vec![1] } else { dims }, crate::util::tensor::DType::F32, data))
            })
            .collect()
    }
}

/// Registry of golden oracles found under an artifacts directory
/// (single-threaded: PJRT objects are Rc-backed).
pub struct OracleRegistry {
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<GoldenOracle>>>,
}

impl OracleRegistry {
    pub fn new(dir: impl Into<PathBuf>) -> OracleRegistry {
        OracleRegistry { dir: dir.into(), cache: RefCell::new(HashMap::new()) }
    }

    /// Default artifacts directory (repo-local `artifacts/`).
    pub fn default_dir() -> OracleRegistry {
        OracleRegistry::new("artifacts")
    }

    /// Is the artifact for `name` present on disk?
    pub fn available(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load (and cache) the oracle for `name`.
    pub fn get(&self, name: &str) -> Result<Rc<GoldenOracle>> {
        if let Some(o) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(o));
        }
        let oracle = Rc::new(GoldenOracle::load(&self.path(name))?);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&oracle));
        Ok(oracle)
    }

    /// All artifact names present.
    pub fn list(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                if let Some(n) = e.file_name().to_str() {
                    if let Some(stem) = n.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests only run when artifacts exist (make artifacts);
    // cargo test stays self-contained without them.

    #[test]
    fn registry_lists_missing_dir_gracefully() {
        let r = OracleRegistry::new("/nonexistent/dir");
        assert!(r.list().is_empty());
        assert!(!r.available("softmax"));
    }

    #[test]
    fn golden_softmax_matches_rust_reference() {
        let reg = OracleRegistry::default_dir();
        if !reg.available("softmax") {
            eprintln!("skipping: artifacts/softmax.hlo.txt not built");
            return;
        }
        let oracle = reg.get("softmax").unwrap();
        let task = crate::bench_suite::tasks::task_by_name("softmax").unwrap();
        let inputs = task.make_inputs(11);
        let want = task.reference(&inputs);
        let got = oracle.run(&[&inputs["x"]]).unwrap();
        assert_eq!(got.len(), 1);
        assert!(crate::util::compare::allclose(&got[0], &want["y"], 1e-4, 1e-5));
    }

    #[test]
    fn golden_gelu_matches_rust_reference() {
        let reg = OracleRegistry::default_dir();
        if !reg.available("gelu") {
            eprintln!("skipping: artifacts/gelu.hlo.txt not built");
            return;
        }
        let oracle = reg.get("gelu").unwrap();
        let task = crate::bench_suite::tasks::task_by_name("gelu").unwrap();
        let inputs = task.make_inputs(13);
        let want = task.reference(&inputs);
        let got = oracle.run(&[&inputs["x"]]).unwrap();
        assert!(crate::util::compare::allclose(&got[0], &want["y"], 1e-3, 1e-4));
    }
}
