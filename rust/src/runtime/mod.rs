//! Golden-oracle runtime: loads AOT-lowered JAX reference computations
//! (HLO text in `artifacts/*.hlo.txt`) and executes them with the
//! self-contained [`hlo`] interpreter. This is the L2 golden oracle — an
//! *independent* numerical reference produced by the JAX build path,
//! cross-checked against the Rust references (L3) and used for Pass@1
//! verification of the showcase kernels.
//!
//! The previous implementation compiled the HLO through a PJRT/XLA CPU
//! client, which made the crate unbuildable without a native
//! `xla_extension` install and confined oracle use to one thread
//! (`Rc`-backed client handles). The interpreter removes both
//! constraints: [`GoldenOracle`] and [`OracleRegistry`] are plain data
//! (`Send + Sync`), so coordinator workers can cross-check suite results
//! against L2 in parallel — the check is folded into
//! [`crate::coordinator::service::run_suite`] via `SuiteConfig::golden`.
//!
//! Execution is compile-once/execute-many: loading an artifact compiles it
//! to an [`hlo::ExecutablePlan`] (call inlining, fused elementwise loop
//! nests, resolved reduce combiners, a liveness-driven buffer arena), and
//! every `run` executes that plan. See `rust/benches/hotpath.rs` for the
//! measured speedup over the retired tree-walking path.

pub mod fixtures;
pub mod hlo;

use crate::util::tensor::Tensor;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Errors from loading or executing a golden oracle.
#[derive(Debug)]
pub enum RuntimeError {
    /// The artifact file could not be read.
    Io { path: PathBuf, err: std::io::Error },
    /// The artifact is not valid HLO text.
    Parse { path: PathBuf, err: hlo::ParseError },
    /// The module loaded but could not be executed on the given inputs.
    Eval { oracle: String, msg: String },
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Io { path, err } => write!(f, "reading {}: {err}", path.display()),
            RuntimeError::Parse { path, err } => {
                write!(f, "parsing HLO text {}: {err}", path.display())
            }
            RuntimeError::Eval { oracle, msg } => write!(f, "executing oracle '{oracle}': {msg}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// A loaded golden computation, executable on host tensors. Parsing and
/// plan compilation happen once at load; every [`GoldenOracle::run`]
/// executes the compiled plan (the tree-walking evaluator remains as a
/// fallback for modules outside the plan compiler's op set).
#[derive(Clone, Debug)]
pub struct GoldenOracle {
    module: hlo::Module,
    plan: Option<hlo::ExecutablePlan>,
    name: String,
}

impl GoldenOracle {
    /// Load and parse an HLO text artifact.
    pub fn load(path: &Path) -> Result<GoldenOracle, RuntimeError> {
        let text = std::fs::read_to_string(path)
            .map_err(|err| RuntimeError::Io { path: path.to_path_buf(), err })?;
        let file = path.file_name().and_then(|s| s.to_str());
        let name = file
            .and_then(|f| f.strip_suffix(".hlo.txt"))
            .or_else(|| path.file_stem().and_then(|s| s.to_str()))
            .unwrap_or("oracle");
        GoldenOracle::parse(name, &text)
            .map_err(|err| RuntimeError::Parse { path: path.to_path_buf(), err })
    }

    /// Parse HLO text directly (used by tests and embedders).
    pub fn from_text(name: &str, text: &str) -> Result<GoldenOracle, RuntimeError> {
        GoldenOracle::parse(name, text)
            .map_err(|err| RuntimeError::Parse { path: PathBuf::from(format!("<{name}>")), err })
    }

    /// Shared parse + plan-compile path behind [`load`] / [`from_text`]
    /// (each caller wraps the parse error with its own path context once).
    fn parse(name: &str, text: &str) -> Result<GoldenOracle, hlo::ParseError> {
        let module = hlo::parse_module(text)?;
        let plan = hlo::ExecutablePlan::compile(&module).ok();
        Ok(GoldenOracle { module, plan, name: name.to_string() })
    }

    /// The oracle name (the artifact file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Did the module compile to an [`hlo::ExecutablePlan`]? When false,
    /// [`run`](GoldenOracle::run) falls back to the tree-walking evaluator.
    pub fn has_plan(&self) -> bool {
        self.plan.is_some()
    }

    /// Number of input tensors the oracle expects.
    pub fn arity(&self) -> usize {
        self.module.entry_computation().params.len()
    }

    /// Dimensions of input parameter `i`, if it exists.
    pub fn input_shape(&self, i: usize) -> Option<&[usize]> {
        let comp = self.module.entry_computation();
        let &idx = comp.params.get(i)?;
        comp.instrs[idx].shape.array().ok().map(|s| s.dims.as_slice())
    }

    /// Execute with f32 tensor inputs; returns the tuple of outputs.
    /// (aot.py lowers with `return_tuple=True`.) Scalar (rank-0) outputs
    /// are reported with shape `[1]`, matching the task-spec convention.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, RuntimeError> {
        let mut scratch = hlo::PlanScratch::default();
        self.run_one(inputs, &mut scratch)
    }

    /// Batched execution: run the oracle once per input set, sharing one
    /// [`hlo::PlanScratch`] across the whole batch. The plan is compiled
    /// once at load time; with the scratch reused, every run after the
    /// first is allocation-free inside the plan executor for `while`-free
    /// plans (`while` steps allocate their per-iteration state; their
    /// nested arenas are still recycled). This is how `suite --golden`
    /// amortizes oracle cost across a task's seeds — see the `oracle`
    /// group in `rust/benches/hotpath.rs` for the measured win over
    /// per-seed [`run`](GoldenOracle::run) calls. Fails on the first
    /// erroring input set; callers that need per-set verdicts run the
    /// sets individually (see
    /// [`crate::coordinator::service::cross_check_task_seeds`]).
    pub fn run_batch(&self, batches: &[Vec<&Tensor>]) -> Result<Vec<Vec<Tensor>>, RuntimeError> {
        let mut scratch = hlo::PlanScratch::default();
        self.run_batch_with_scratch(batches, &mut scratch)
    }

    /// [`run_batch`](GoldenOracle::run_batch) with a caller-owned scratch,
    /// for callers that execute many batches (benches, long-lived workers).
    pub fn run_batch_with_scratch(
        &self,
        batches: &[Vec<&Tensor>],
        scratch: &mut hlo::PlanScratch,
    ) -> Result<Vec<Vec<Tensor>>, RuntimeError> {
        batches.iter().map(|inputs| self.run_one(inputs, scratch)).collect()
    }

    /// One execution against a caller-provided scratch: the shared body of
    /// [`run`](GoldenOracle::run) and [`run_batch`](GoldenOracle::run_batch).
    fn run_one(
        &self,
        inputs: &[&Tensor],
        scratch: &mut hlo::PlanScratch,
    ) -> Result<Vec<Tensor>, RuntimeError> {
        let outs = match &self.plan {
            Some(plan) => plan.execute_with_scratch(inputs, scratch),
            None => hlo::evaluate(&self.module, inputs),
        }
        .map_err(|msg| RuntimeError::Eval { oracle: self.name.clone(), msg })?;
        Ok(outs
            .into_iter()
            .map(|t| if t.shape.is_empty() { t.reshape(&[1]) } else { t })
            .collect())
    }
}

/// Registry of golden oracles found under an artifacts directory. Loaded
/// modules are cached behind a mutex; `Arc` handles let many worker
/// threads execute the same oracle concurrently (evaluation is pure).
pub struct OracleRegistry {
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<GoldenOracle>>>,
}

impl OracleRegistry {
    /// A registry over `dir` (expects `<name>.hlo.txt` artifact files).
    pub fn new(dir: impl Into<PathBuf>) -> OracleRegistry {
        OracleRegistry { dir: dir.into(), cache: Mutex::new(HashMap::new()) }
    }

    /// Default artifacts directory: the repo-root `artifacts/` (resolved
    /// relative to this crate at compile time so `cargo test` finds the
    /// checked-in fixtures from any working directory), falling back to a
    /// cwd-relative `artifacts/`.
    pub fn default_dir() -> OracleRegistry {
        let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts");
        if repo.is_dir() {
            OracleRegistry::new(repo)
        } else {
            OracleRegistry::new("artifacts")
        }
    }

    /// Is the artifact for `name` present on disk?
    pub fn available(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load (and cache) the oracle for `name`.
    pub fn get(&self, name: &str) -> Result<Arc<GoldenOracle>, RuntimeError> {
        if let Some(o) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(o));
        }
        // parse outside the lock: artifacts parse in microseconds but
        // there is no reason to serialize workers on it
        let oracle = Arc::new(GoldenOracle::load(&self.path(name))?);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.entry(name.to_string()).or_insert_with(|| Arc::clone(&oracle));
        Ok(Arc::clone(entry))
    }

    /// All artifact names present, sorted.
    pub fn list(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                if let Some(n) = e.file_name().to_str() {
                    if let Some(stem) = n.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_types_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<GoldenOracle>();
        assert_send_sync::<OracleRegistry>();
        assert_send_sync::<RuntimeError>();
    }

    #[test]
    fn registry_lists_missing_dir_gracefully() {
        let r = OracleRegistry::new("/nonexistent/dir");
        assert!(r.list().is_empty());
        assert!(!r.available("softmax"));
        assert!(r.get("softmax").is_err());
    }

    #[test]
    fn default_dir_finds_checked_in_fixtures() {
        let reg = OracleRegistry::default_dir();
        let names = reg.list();
        assert!(
            names.iter().any(|n| n == "softmax") && names.iter().any(|n| n == "gelu"),
            "checked-in artifacts/ fixtures missing: {names:?}"
        );
    }

    #[test]
    fn load_strips_the_full_artifact_suffix() {
        let reg = OracleRegistry::default_dir();
        let oracle = reg.get("softmax").expect("softmax.hlo.txt is checked in");
        assert_eq!(oracle.name(), "softmax");
    }

    #[test]
    fn oracle_falls_back_to_evaluator_without_a_plan() {
        // `frobnicate` parses (Opcode::Other) but is outside the plan
        // compiler's op set; the oracle must still load, report no plan,
        // and surface the evaluator's error at run time
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT y = f32[2]{0} frobnicate(x)\n}\n";
        let oracle = GoldenOracle::from_text("frob", text).unwrap();
        assert!(!oracle.has_plan());
        let x = Tensor::from_vec(vec![1.0, 2.0]);
        let err = oracle.run(&[&x]).unwrap_err();
        assert!(err.to_string().contains("frobnicate"), "{err}");
    }

    #[test]
    fn golden_softmax_matches_rust_reference() {
        let reg = OracleRegistry::default_dir();
        let oracle = reg.get("softmax").expect("softmax.hlo.txt is checked in");
        let task = crate::bench_suite::tasks::task_by_name("softmax").unwrap();
        let inputs = task.make_inputs(11);
        let want = task.reference(&inputs);
        let got = oracle.run(&[&inputs["x"]]).unwrap();
        assert_eq!(got.len(), 1);
        assert!(crate::util::compare::allclose(&got[0], &want["y"], 1e-4, 1e-5));
    }

    #[test]
    fn golden_gelu_matches_rust_reference() {
        let reg = OracleRegistry::default_dir();
        let oracle = reg.get("gelu").expect("gelu.hlo.txt is checked in");
        let task = crate::bench_suite::tasks::task_by_name("gelu").unwrap();
        let inputs = task.make_inputs(13);
        let want = task.reference(&inputs);
        let got = oracle.run(&[&inputs["x"]]).unwrap();
        assert!(crate::util::compare::allclose(&got[0], &want["y"], 1e-3, 1e-4));
    }

    #[test]
    fn oracle_reports_shape_mismatch() {
        let reg = OracleRegistry::default_dir();
        let oracle = reg.get("softmax").expect("softmax.hlo.txt is checked in");
        let wrong = Tensor::zeros(&[2, 2]);
        let err = oracle.run(&[&wrong]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("shape"), "{msg}");
    }

    #[test]
    fn run_batch_matches_per_seed_runs_bitwise() {
        let reg = OracleRegistry::default_dir();
        let oracle = reg.get("softmax").expect("softmax.hlo.txt is checked in");
        let dims = oracle.input_shape(0).unwrap().to_vec();
        let n: usize = dims.iter().product();
        let inputs: Vec<Tensor> = (0..4u64)
            .map(|seed| {
                let mut rng = crate::util::rng::XorShiftRng::new(0xBA7C4 + seed);
                Tensor::new(dims.clone(), crate::util::tensor::DType::F32, rng.normal_vec(n))
            })
            .collect();
        let batches: Vec<Vec<&Tensor>> = inputs.iter().map(|t| vec![t]).collect();
        let batched = oracle.run_batch(&batches).unwrap();
        assert_eq!(batched.len(), 4);
        for (ins, outs) in batches.iter().zip(&batched) {
            let single = oracle.run(ins).unwrap();
            assert_eq!(single.len(), outs.len());
            for (a, b) in single.iter().zip(outs) {
                assert_eq!(a.shape, b.shape);
                assert_eq!(a.data, b.data, "batched run diverged from per-seed run");
            }
        }
    }

    #[test]
    fn run_batch_falls_back_to_the_evaluator_without_a_plan() {
        // an op outside the plan compiler's set but inside the evaluator's
        // would be needed to hit the fallback with real outputs; `frobnicate`
        // is outside both, so the batch must surface the evaluator error
        // for every input set
        let text = "HloModule t\n\nENTRY e {\n  x = f32[2]{0} parameter(0)\n  ROOT y = f32[2]{0} frobnicate(x)\n}\n";
        let oracle = GoldenOracle::from_text("frob", text).unwrap();
        let x = Tensor::from_vec(vec![1.0, 2.0]);
        let err = oracle.run_batch(&[vec![&x]]).unwrap_err();
        assert!(err.to_string().contains("frobnicate"), "{err}");
    }

    #[test]
    fn registry_is_shareable_across_threads() {
        let reg = std::sync::Arc::new(OracleRegistry::default_dir());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let reg = std::sync::Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                let oracle = reg.get("relu").expect("relu.hlo.txt is checked in");
                let x = Tensor::from_vec(vec![-1.0; 1024 * 4096]);
                // full-shape run in every thread: exercises concurrent use
                let x = x.reshape(&[1024, 4096]);
                let out = oracle.run(&[&x]).unwrap();
                assert!(out[0].data.iter().all(|&v| v == 0.0));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
