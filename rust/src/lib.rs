//! AscendCraft: DSL-guided transcompilation for Ascend NPU kernel generation.
pub mod analysis;
pub mod ascendc;
pub mod backend;
pub mod baselines;
pub mod bench_suite;
pub mod coordinator;
pub mod diag;
pub mod dsl;
pub mod mhc;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod synth;
pub mod transpile;
pub mod tune;
pub mod util;
