//! Recursive-descent parser for the AscendCraft DSL.
//!
//! Grammar (informal):
//! ```text
//! program   := (import | kernel_def | host_def)*
//! kernel_def:= '@' 'ascend_kernel' NEWLINE 'def' IDENT '(' params ')' ':' block
//! host_def  := 'def' IDENT '(' params ')' ':' block
//! block     := NEWLINE INDENT stmt+ DEDENT
//! stmt      := assign | augassign | for | while | if | with_stage
//!            | launch | expr_stmt | 'pass' | 'return' [expr]
//! for       := 'for' IDENT 'in' 'range' '(' expr [',' expr [',' expr]] ')' ':' block
//! with_stage:= 'with' ('tl.copyin'|'tl.compute'|'tl.copyout') '(' ')' ':' block
//! launch    := IDENT '[' expr ']' '(' exprlist ')'
//! ```
//! Expressions use Python precedence: `or < and < not < comparison <
//! add/sub < mul/div/floordiv/mod < unary < power < postfix`.

use super::ast::*;
use super::lexer::{lex, Tok, Token};
use std::fmt;

#[derive(Clone, Debug)]
pub struct ParseError {
    pub message: String,
    pub line: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

pub fn parse_program(source: &str) -> Result<DslProgram, ParseError> {
    let tokens =
        lex(source).map_err(|e| ParseError { message: e.message, line: e.line })?;
    Parser { tokens, pos: 0 }.program()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.tokens[self.pos].tok
    }

    fn peek2(&self) -> &Tok {
        self.tokens.get(self.pos + 1).map(|t| &t.tok).unwrap_or(&Tok::Eof)
    }

    fn line(&self) -> usize {
        self.tokens[self.pos].line
    }

    fn bump(&mut self) -> Tok {
        let t = self.tokens[self.pos].tok.clone();
        if self.pos + 1 < self.tokens.len() {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if self.peek() == tok {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: Tok) -> Result<(), ParseError> {
        if self.peek() == &tok {
            self.bump();
            Ok(())
        } else {
            Err(self.err(format!("expected {tok}, found {}", self.peek())))
        }
    }

    fn err(&self, message: String) -> ParseError {
        ParseError { message, line: self.line() }
    }

    fn ident(&mut self) -> Result<String, ParseError> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => Err(ParseError {
                message: format!("expected identifier, found {other}"),
                line: self.tokens[self.pos.saturating_sub(1)].line,
            }),
        }
    }

    /// Dotted name: IDENT ('.' IDENT)* joined with '.'.
    fn dotted_name(&mut self) -> Result<String, ParseError> {
        let mut name = self.ident()?;
        while self.peek() == &Tok::Dot {
            self.bump();
            name.push('.');
            name.push_str(&self.ident()?);
        }
        Ok(name)
    }

    fn program(&mut self) -> Result<DslProgram, ParseError> {
        let mut kernels: Vec<KernelFn> = Vec::new();
        let mut hosts: Vec<HostFn> = Vec::new();
        loop {
            match self.peek() {
                Tok::Eof => break,
                Tok::Newline => {
                    self.bump();
                }
                Tok::Import => {
                    self.skip_import()?;
                }
                Tok::At => {
                    self.bump();
                    let deco = self.dotted_name()?;
                    if deco != "ascend_kernel" && deco != "tl.ascend_kernel" {
                        return Err(self.err(format!("unknown decorator '@{deco}'")));
                    }
                    self.expect(Tok::Newline)?;
                    let f = self.def()?;
                    kernels.push(KernelFn { name: f.0, params: f.1, body: f.2, line: f.3 });
                }
                Tok::Def => {
                    let f = self.def()?;
                    hosts.push(HostFn { name: f.0, params: f.1, body: f.2, line: f.3 });
                }
                other => {
                    return Err(self.err(format!(
                        "expected import / @ascend_kernel / def at top level, found {other}"
                    )))
                }
            }
        }
        if kernels.is_empty() {
            return Err(ParseError { message: "program has no @ascend_kernel function".into(), line: 1 });
        }
        let host = hosts
            .pop()
            .ok_or(ParseError { message: "program has no host function".into(), line: 1 })?;
        let kernel = kernels.remove(0);
        Ok(DslProgram { kernel, host, extra_kernels: kernels })
    }

    fn skip_import(&mut self) -> Result<(), ParseError> {
        self.expect(Tok::Import)?;
        self.dotted_name()?;
        if self.eat(&Tok::As) {
            self.ident()?;
        }
        self.expect(Tok::Newline)
    }

    /// Parse `def name(params): block`; returns (name, params, body, line).
    fn def(&mut self) -> Result<(String, Vec<Param>, Vec<Stmt>, usize), ParseError> {
        let line = self.line();
        self.expect(Tok::Def)?;
        let name = self.ident()?;
        self.expect(Tok::LParen)?;
        let mut params = Vec::new();
        while self.peek() != &Tok::RParen {
            let pname = self.ident()?;
            // optional annotation `: torch.Tensor`
            if self.eat(&Tok::Colon) {
                self.dotted_name()?;
            }
            // optional default `= expr`
            if self.eat(&Tok::Assign) {
                self.expr()?;
            }
            params.push(Param { name: pname });
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RParen)?;
        // optional return annotation
        if self.eat(&Tok::Arrow) {
            self.dotted_name()?;
        }
        self.expect(Tok::Colon)?;
        let body = self.block()?;
        Ok((name, params, body, line))
    }

    fn block(&mut self) -> Result<Vec<Stmt>, ParseError> {
        self.expect(Tok::Newline)?;
        self.expect(Tok::Indent)?;
        let mut stmts = Vec::new();
        while self.peek() != &Tok::Dedent && self.peek() != &Tok::Eof {
            if self.eat(&Tok::Newline) {
                continue;
            }
            stmts.push(self.stmt()?);
        }
        self.expect(Tok::Dedent)?;
        if stmts.is_empty() {
            return Err(self.err("empty block".into()));
        }
        Ok(stmts)
    }

    fn stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        match self.peek().clone() {
            Tok::Pass => {
                self.bump();
                self.expect(Tok::Newline)?;
                Ok(Stmt::Pass { line })
            }
            Tok::Return => {
                self.bump();
                let value =
                    if self.peek() == &Tok::Newline { None } else { Some(self.expr()?) };
                self.expect(Tok::Newline)?;
                Ok(Stmt::Return { value, line })
            }
            Tok::For => self.for_stmt(),
            Tok::While => {
                self.bump();
                let cond = self.expr()?;
                self.expect(Tok::Colon)?;
                let body = self.block()?;
                Ok(Stmt::While { cond, body, line })
            }
            Tok::If => self.if_stmt(),
            Tok::With => self.with_stmt(),
            Tok::Ident(name) => {
                // launch: IDENT '[' expr ']' '(' ... ')'
                if self.peek2() == &Tok::LBracket {
                    return self.launch_stmt(name);
                }
                // assignment or expression statement
                self.assign_or_expr_stmt()
            }
            _ => self.assign_or_expr_stmt(),
        }
    }

    fn launch_stmt(&mut self, kernel: String) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.bump(); // ident
        self.expect(Tok::LBracket)?;
        let grid = self.expr()?;
        self.expect(Tok::RBracket)?;
        self.expect(Tok::LParen)?;
        let mut args = Vec::new();
        while self.peek() != &Tok::RParen {
            args.push(self.expr()?);
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::Newline)?;
        Ok(Stmt::Launch { kernel, grid, args, line })
    }

    fn for_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.expect(Tok::For)?;
        let var = self.ident()?;
        self.expect(Tok::In)?;
        self.expect(Tok::Range)?;
        self.expect(Tok::LParen)?;
        let first = self.expr()?;
        let (start, end, step) = if self.eat(&Tok::Comma) {
            let second = self.expr()?;
            if self.eat(&Tok::Comma) {
                let third = self.expr()?;
                (first, second, Some(third))
            } else {
                (first, second, None)
            }
        } else {
            (Expr::Int(0), first, None)
        };
        self.expect(Tok::RParen)?;
        self.expect(Tok::Colon)?;
        let body = self.block()?;
        Ok(Stmt::For { var, start, end, step, body, line })
    }

    fn if_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        // 'if' or 'elif' already distinguished by caller
        self.bump();
        let cond = self.expr()?;
        self.expect(Tok::Colon)?;
        let then = self.block()?;
        let orelse = match self.peek() {
            Tok::Elif => vec![self.if_stmt()?],
            Tok::Else => {
                self.bump();
                self.expect(Tok::Colon)?;
                self.block()?
            }
            _ => vec![],
        };
        Ok(Stmt::If { cond, then, orelse, line })
    }

    fn with_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.expect(Tok::With)?;
        let name = self.dotted_name()?;
        let stage = match name.as_str() {
            "tl.copyin" => Stage::CopyIn,
            "tl.compute" => Stage::Compute,
            "tl.copyout" => Stage::CopyOut,
            other => return Err(self.err(format!("unknown with-context '{other}' (expected tl.copyin/tl.compute/tl.copyout)"))),
        };
        self.expect(Tok::LParen)?;
        self.expect(Tok::RParen)?;
        self.expect(Tok::Colon)?;
        let body = self.block()?;
        Ok(Stmt::WithStage { stage, body, line })
    }

    fn assign_or_expr_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        // Try: IDENT (=|+=|-=|*=|/=) expr
        if let Tok::Ident(name) = self.peek().clone() {
            let op = match self.peek2() {
                Tok::Assign => Some(None),
                Tok::PlusEq => Some(Some(BinOp::Add)),
                Tok::MinusEq => Some(Some(BinOp::Sub)),
                Tok::TimesEq => Some(Some(BinOp::Mul)),
                Tok::DivEq => Some(Some(BinOp::Div)),
                _ => None,
            };
            if let Some(maybe_op) = op {
                self.bump(); // ident
                self.bump(); // op
                let value = self.expr()?;
                self.expect(Tok::Newline)?;
                return Ok(match maybe_op {
                    None => Stmt::Assign { target: name, value, line },
                    Some(op) => Stmt::AugAssign { target: name, op, value, line },
                });
            }
        }
        let expr = self.expr()?;
        self.expect(Tok::Newline)?;
        Ok(Stmt::ExprStmt { expr, line })
    }

    // ---- expressions ----

    fn expr(&mut self) -> Result<Expr, ParseError> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while self.eat(&Tok::Or) {
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.not_expr()?;
        while self.eat(&Tok::And) {
            let rhs = self.not_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> Result<Expr, ParseError> {
        if self.eat(&Tok::Not) {
            let e = self.not_expr()?;
            return Ok(Expr::Un(UnOp::Not, Box::new(e)));
        }
        self.comparison()
    }

    fn comparison(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Lt => BinOp::Lt,
            Tok::Le => BinOp::Le,
            Tok::Gt => BinOp::Gt,
            Tok::Ge => BinOp::Ge,
            Tok::EqEq => BinOp::Eq,
            Tok::NotEq => BinOp::Ne,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.add_expr()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::SlashSlash => BinOp::FloorDiv,
                Tok::Percent => BinOp::Mod,
                _ => break,
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr, ParseError> {
        if self.eat(&Tok::Minus) {
            let e = self.unary_expr()?;
            // fold literal negation so `-1e30` is a literal
            return Ok(match e {
                Expr::Int(v) => Expr::Int(-v),
                Expr::Float(v) => Expr::Float(-v),
                other => Expr::Un(UnOp::Neg, Box::new(other)),
            });
        }
        if self.eat(&Tok::Plus) {
            return self.unary_expr();
        }
        self.power_expr()
    }

    fn power_expr(&mut self) -> Result<Expr, ParseError> {
        let base = self.postfix_expr()?;
        if self.eat(&Tok::StarStar) {
            let exp = self.unary_expr()?; // right-assoc
            return Ok(Expr::Bin(BinOp::Pow, Box::new(base), Box::new(exp)));
        }
        Ok(base)
    }

    fn postfix_expr(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.primary()?;
        loop {
            match self.peek() {
                Tok::LParen => {
                    let func = match &e {
                        Expr::Name(n) => n.clone(),
                        _ => return Err(self.err("can only call named functions".into())),
                    };
                    self.bump();
                    let (args, kwargs) = self.call_args()?;
                    e = Expr::Call { func, args, kwargs };
                }
                Tok::LBracket => {
                    self.bump();
                    let index = self.expr()?;
                    self.expect(Tok::RBracket)?;
                    e = Expr::Index { base: Box::new(e), index: Box::new(index) };
                }
                Tok::Dot => {
                    self.bump();
                    let attr = self.ident()?;
                    match e {
                        Expr::Name(n) => e = Expr::Name(format!("{n}.{attr}")),
                        _ => return Err(self.err("attribute access only on names".into())),
                    }
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn call_args(&mut self) -> Result<(Vec<Expr>, Vec<(String, Expr)>), ParseError> {
        let mut args = Vec::new();
        let mut kwargs = Vec::new();
        while self.peek() != &Tok::RParen {
            // kwarg? IDENT '=' expr (but not IDENT '==')
            if let Tok::Ident(name) = self.peek().clone() {
                if self.peek2() == &Tok::Assign {
                    self.bump();
                    self.bump();
                    let v = self.expr()?;
                    kwargs.push((name, v));
                    if !self.eat(&Tok::Comma) {
                        break;
                    }
                    continue;
                }
            }
            args.push(self.expr()?);
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RParen)?;
        Ok((args, kwargs))
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        let line = self.line();
        match self.bump() {
            Tok::Int(v) => Ok(Expr::Int(v)),
            Tok::Float(v) => Ok(Expr::Float(v)),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::True => Ok(Expr::Bool(true)),
            Tok::False => Ok(Expr::Bool(false)),
            Tok::None_ => Ok(Expr::Name("None".into())),
            Tok::Ident(name) => Ok(Expr::Name(name)),
            Tok::Range => Ok(Expr::Name("range".into())), // range used as value is checked later
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            other => Err(ParseError { message: format!("unexpected {other} in expression"), line }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SOFTMAX: &str = r#"
import tile.language as tl

@ascend_kernel
def softmax_kernel(input_ptr, output_ptr, rows_per_core, tile_length, n_tiles):
    pid = tl.program_id(0)
    row_start_idx = pid * rows_per_core
    row_end_idx = row_start_idx + rows_per_core
    row_tile_ub = tl.alloc_ub(tile_length, dtype=tl.float32)
    shared_ub = tl.alloc_ub(8, dtype=tl.float32)
    for row_idx in range(row_start_idx, row_end_idx):
        row_max = -1e30
        for tile_id in range(n_tiles):
            col_start = tile_id * tile_length
            offsets = row_idx * (tile_length * n_tiles) + col_start
            with tl.copyin():
                tl.load(input_ptr + offsets, row_tile_ub, tile_length)
            with tl.compute():
                tl.reduce_max(shared_ub, row_tile_ub, tile_length)
                row_max = tl.max(row_max, tl.extract_scalar(shared_ub, 0))

def softmax_host(x, output):
    rows = x.shape[0]
    cols = x.shape[1]
    n_cores = 32
    rows_per_core = rows // n_cores
    max_tile_len = 4096
    tile_length = min(max_tile_len, cols)
    n_tiles = (cols + tile_length - 1) // tile_length
    softmax_kernel[n_cores](x, output, rows_per_core, tile_length, n_tiles)
"#;

    #[test]
    fn parses_figure2_style_softmax() {
        let p = parse_program(SOFTMAX).unwrap();
        assert_eq!(p.kernel.name, "softmax_kernel");
        assert_eq!(p.kernel.params.len(), 5);
        assert_eq!(p.host.name, "softmax_host");
        assert!(p.extra_kernels.is_empty());
    }

    #[test]
    fn host_has_launch_with_grid() {
        let p = parse_program(SOFTMAX).unwrap();
        let launch = p
            .host
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::Launch { kernel, grid, args, .. } => Some((kernel.clone(), grid.clone(), args.len())),
                _ => None,
            })
            .expect("launch statement");
        assert_eq!(launch.0, "softmax_kernel");
        assert_eq!(launch.1, Expr::name("n_cores"));
        assert_eq!(launch.2, 5);
    }

    #[test]
    fn kernel_contains_stage_blocks() {
        let p = parse_program(SOFTMAX).unwrap();
        let mut stages = vec![];
        for s in &p.kernel.body {
            s.walk(&mut |st| {
                if let Stmt::WithStage { stage, .. } = st {
                    stages.push(*stage);
                }
            });
        }
        assert_eq!(stages, vec![Stage::CopyIn, Stage::Compute]);
    }

    #[test]
    fn range_single_arg_defaults_start_zero() {
        let p = parse_program(SOFTMAX).unwrap();
        let mut found = false;
        for s in &p.kernel.body {
            s.walk(&mut |st| {
                if let Stmt::For { var, start, .. } = st {
                    if var == "tile_id" {
                        assert_eq!(start, &Expr::Int(0));
                        found = true;
                    }
                }
            });
        }
        assert!(found);
    }

    #[test]
    fn alloc_with_dtype_kwarg() {
        let p = parse_program(SOFTMAX).unwrap();
        let alloc = p
            .kernel
            .body
            .iter()
            .find_map(|s| match s {
                Stmt::Assign { target, value, .. } if target == "row_tile_ub" => Some(value.clone()),
                _ => None,
            })
            .unwrap();
        let (kind, _, dtype) = crate::dsl::ast::as_alloc(&alloc).unwrap();
        assert_eq!(kind, AllocKind::Ub);
        assert_eq!(dtype, crate::util::tensor::DType::F32);
    }

    #[test]
    fn if_elif_else() {
        let src = "
@ascend_kernel
def k(a):
    x = 1
    if a > 0:
        x = 2
    elif a < 0:
        x = 3
    else:
        x = 4

def h(t):
    k[1](t)
";
        let p = parse_program(src).unwrap();
        let has_if = p.kernel.body.iter().any(|s| matches!(s, Stmt::If { orelse, .. } if !orelse.is_empty()));
        assert!(has_if);
    }

    #[test]
    fn augmented_assignment() {
        let src = "
@ascend_kernel
def k(a):
    x = 0
    x += 1
    x *= 2

def h(t):
    k[1](t)
";
        let p = parse_program(src).unwrap();
        let augs: Vec<_> = p
            .kernel
            .body
            .iter()
            .filter_map(|s| match s {
                Stmt::AugAssign { op, .. } => Some(*op),
                _ => None,
            })
            .collect();
        assert_eq!(augs, vec![BinOp::Add, BinOp::Mul]);
    }

    #[test]
    fn missing_kernel_is_error() {
        let err = parse_program("def h(x):\n    y = 1\n").unwrap_err();
        assert!(err.message.contains("no @ascend_kernel"));
    }

    #[test]
    fn missing_host_is_error() {
        let err = parse_program("@ascend_kernel\ndef k(x):\n    y = 1\n").unwrap_err();
        assert!(err.message.contains("no host function"));
    }

    #[test]
    fn unknown_with_context_is_error() {
        let src = "
@ascend_kernel
def k(a):
    with tl.compute_fast():
        pass

def h(t):
    k[1](t)
";
        let err = parse_program(src).unwrap_err();
        assert!(err.message.contains("unknown with-context"));
    }

    #[test]
    fn unknown_decorator_is_error() {
        let err = parse_program("@gpu_kernel\ndef k(x):\n    pass\n").unwrap_err();
        assert!(err.message.contains("unknown decorator"));
    }

    #[test]
    fn multi_kernel_program() {
        let src = "
@ascend_kernel
def k1(a):
    pass

@ascend_kernel
def k2(a):
    pass

def h(t):
    k1[4](t)
    k2[1](t)
";
        let p = parse_program(src).unwrap();
        assert_eq!(p.kernel.name, "k1");
        assert_eq!(p.extra_kernels.len(), 1);
        assert_eq!(p.extra_kernels[0].name, "k2");
        assert!(p.kernel_by_name("k2").is_some());
    }

    #[test]
    fn operator_precedence() {
        let src = "
@ascend_kernel
def k(a):
    x = 1 + 2 * 3

def h(t):
    k[1](t)
";
        let p = parse_program(src).unwrap();
        match &p.kernel.body[0] {
            Stmt::Assign { value: Expr::Bin(BinOp::Add, l, r), .. } => {
                assert_eq!(**l, Expr::Int(1));
                assert!(matches!(**r, Expr::Bin(BinOp::Mul, _, _)));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn negative_literal_folding() {
        let src = "
@ascend_kernel
def k(a):
    x = -1e30

def h(t):
    k[1](t)
";
        let p = parse_program(src).unwrap();
        assert!(matches!(&p.kernel.body[0], Stmt::Assign { value: Expr::Float(v), .. } if *v == -1e30));
    }
}
