//! Abstract syntax tree for the AscendCraft DSL (paper §3, Figure 2).
//!
//! A `DslProgram` is one `@ascend_kernel` function plus one host function.
//! Kernel bodies are statement lists with three distinguished `with` stages
//! (`tl.copyin()`, `tl.compute()`, `tl.copyout()`); host bodies are scalar
//! planning code ending in a `kernel[n_cores](...)` launch.

use crate::util::tensor::DType;

/// Execution stage of a `with tl.<stage>():` block — the paper's staged
/// execution model, preserved all the way into AscendC stage functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    CopyIn,
    Compute,
    CopyOut,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::CopyIn => "copyin",
            Stage::Compute => "compute",
            Stage::CopyOut => "copyout",
        }
    }
}

/// Binary operators on scalars (host + kernel index arithmetic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,      // float division
    FloorDiv, // //
    Mod,
    Pow,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

/// Expressions. The DSL deliberately keeps one expression grammar for both
/// host and kernel; validation decides which calls are legal where.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    /// Variable reference (`tile_length`) or dotted name (`tl.float32`,
    /// `x.shape`) — dotted paths are kept as a joined name for simplicity.
    Name(String),
    /// Binary operation.
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Un(UnOp, Box<Expr>),
    /// Function call: callee is a dotted name (`tl.load`, `min`, `range`).
    Call { func: String, args: Vec<Expr>, kwargs: Vec<(String, Expr)> },
    /// Subscript `base[index]` (e.g. `x.shape[0]`, `buf[i]`).
    Index { base: Box<Expr>, index: Box<Expr> },
}

impl Expr {
    pub fn call(func: &str, args: Vec<Expr>) -> Expr {
        Expr::Call { func: func.to_string(), args, kwargs: vec![] }
    }

    pub fn name(n: &str) -> Expr {
        Expr::Name(n.to_string())
    }

    /// Walk every sub-expression (including self), calling `f`.
    pub fn walk(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Bin(_, a, b) => {
                a.walk(f);
                b.walk(f);
            }
            Expr::Un(_, a) => a.walk(f),
            Expr::Call { args, kwargs, .. } => {
                for a in args {
                    a.walk(f);
                }
                for (_, v) in kwargs {
                    v.walk(f);
                }
            }
            Expr::Index { base, index } => {
                base.walk(f);
                index.walk(f);
            }
            _ => {}
        }
    }
}

/// Statements.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `name = expr` — also covers `buf = tl.alloc_ub(...)`.
    Assign { target: String, value: Expr, line: usize },
    /// Augmented assignment `x += e` etc., desugared op retained.
    AugAssign { target: String, op: BinOp, value: Expr, line: usize },
    /// `for var in range(start, end[, step]):`
    For { var: String, start: Expr, end: Expr, step: Option<Expr>, body: Vec<Stmt>, line: usize },
    /// `while cond:` (used rarely; kept for expressiveness)
    While { cond: Expr, body: Vec<Stmt>, line: usize },
    /// `if cond: ... [elif/else ...]` — elif chains are nested If in else.
    If { cond: Expr, then: Vec<Stmt>, orelse: Vec<Stmt>, line: usize },
    /// `with tl.copyin():` etc.
    WithStage { stage: Stage, body: Vec<Stmt>, line: usize },
    /// Bare call expression statement (`tl.store(...)`).
    ExprStmt { expr: Expr, line: usize },
    /// `kernel_name[grid_expr](arg, ...)` — host-side launch.
    Launch { kernel: String, grid: Expr, args: Vec<Expr>, line: usize },
    /// `pass`
    Pass { line: usize },
    /// `return expr?` (host only)
    Return { value: Option<Expr>, line: usize },
}

impl Stmt {
    pub fn line(&self) -> usize {
        match self {
            Stmt::Assign { line, .. }
            | Stmt::AugAssign { line, .. }
            | Stmt::For { line, .. }
            | Stmt::While { line, .. }
            | Stmt::If { line, .. }
            | Stmt::WithStage { line, .. }
            | Stmt::ExprStmt { line, .. }
            | Stmt::Launch { line, .. }
            | Stmt::Pass { line }
            | Stmt::Return { line, .. } => *line,
        }
    }

    /// Recursively visit this statement and all nested statements.
    pub fn walk(&self, f: &mut impl FnMut(&Stmt)) {
        f(self);
        match self {
            Stmt::For { body, .. } | Stmt::While { body, .. } | Stmt::WithStage { body, .. } => {
                for s in body {
                    s.walk(f);
                }
            }
            Stmt::If { then, orelse, .. } => {
                for s in then {
                    s.walk(f);
                }
                for s in orelse {
                    s.walk(f);
                }
            }
            _ => {}
        }
    }
}

/// A kernel parameter. Pointer parameters are global-tensor handles; scalar
/// parameters carry tiling values from the host.
#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub name: String,
}

/// The `@ascend_kernel` function.
#[derive(Clone, Debug, PartialEq)]
pub struct KernelFn {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    pub line: usize,
}

/// The host function.
#[derive(Clone, Debug, PartialEq)]
pub struct HostFn {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    pub line: usize,
}

/// A complete DSL program.
#[derive(Clone, Debug, PartialEq)]
pub struct DslProgram {
    pub kernel: KernelFn,
    pub host: HostFn,
    /// Additional kernels (multi-kernel programs, e.g. two-phase reductions
    /// with a cross-core combine kernel).
    pub extra_kernels: Vec<KernelFn>,
}

impl DslProgram {
    /// All kernels, primary first.
    pub fn kernels(&self) -> impl Iterator<Item = &KernelFn> {
        std::iter::once(&self.kernel).chain(self.extra_kernels.iter())
    }

    pub fn kernel_by_name(&self, name: &str) -> Option<&KernelFn> {
        self.kernels().find(|k| k.name == name)
    }
}

/// Buffer allocation kinds in the kernel (`tl.alloc_ub` / `tl.alloc_l1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocKind {
    Ub,
    L1,
}

/// Recognize a buffer-allocation call; returns (kind, length expr, dtype).
pub fn as_alloc(expr: &Expr) -> Option<(AllocKind, &Expr, DType)> {
    if let Expr::Call { func, args, kwargs } = expr {
        let kind = match func.as_str() {
            "tl.alloc_ub" => AllocKind::Ub,
            "tl.alloc_l1" => AllocKind::L1,
            _ => return None,
        };
        let len = args.first()?;
        let dtype = kwargs
            .iter()
            .find(|(k, _)| k == "dtype")
            .and_then(|(_, v)| match v {
                Expr::Name(n) => DType::parse_dsl(n),
                _ => None,
            })
            .unwrap_or(DType::F32);
        return Some((kind, len, dtype));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names() {
        assert_eq!(Stage::CopyIn.name(), "copyin");
        assert_eq!(Stage::Compute.name(), "compute");
        assert_eq!(Stage::CopyOut.name(), "copyout");
    }

    #[test]
    fn as_alloc_recognizes_ub() {
        let e = Expr::Call {
            func: "tl.alloc_ub".into(),
            args: vec![Expr::Name("tile_length".into())],
            kwargs: vec![("dtype".into(), Expr::Name("tl.float16".into()))],
        };
        let (kind, len, dtype) = as_alloc(&e).unwrap();
        assert_eq!(kind, AllocKind::Ub);
        assert_eq!(len, &Expr::Name("tile_length".into()));
        assert_eq!(dtype, DType::F16);
    }

    #[test]
    fn as_alloc_defaults_to_f32() {
        let e = Expr::call("tl.alloc_ub", vec![Expr::Int(128)]);
        let (_, _, dtype) = as_alloc(&e).unwrap();
        assert_eq!(dtype, DType::F32);
    }

    #[test]
    fn as_alloc_rejects_other_calls() {
        let e = Expr::call("tl.load", vec![]);
        assert!(as_alloc(&e).is_none());
    }

    #[test]
    fn expr_walk_visits_all() {
        let e = Expr::Bin(
            BinOp::Add,
            Box::new(Expr::call("f", vec![Expr::Int(1)])),
            Box::new(Expr::Index { base: Box::new(Expr::name("x")), index: Box::new(Expr::Int(0)) }),
        );
        let mut n = 0;
        e.walk(&mut |_| n += 1);
        assert_eq!(n, 6);
    }

    #[test]
    fn stmt_walk_recurses_into_stage() {
        let s = Stmt::WithStage {
            stage: Stage::Compute,
            body: vec![Stmt::Pass { line: 2 }],
            line: 1,
        };
        let mut lines = vec![];
        s.walk(&mut |st| lines.push(st.line()));
        assert_eq!(lines, vec![1, 2]);
    }
}
