//! Indentation-aware lexer for the AscendCraft DSL.
//!
//! Produces a flat token stream with explicit `Indent` / `Dedent` tokens in
//! the Python style: at the start of each logical line, the leading-space
//! count is compared against the indent stack. Blank lines and `#` comments
//! are skipped. Line continuations inside brackets are handled by tracking
//! bracket depth (like Python's implicit joining).

use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // structure
    Newline,
    Indent,
    Dedent,
    // words
    Ident(String),
    Int(i64),
    Float(f64),
    Str(String),
    // keywords
    Def,
    For,
    While,
    If,
    Elif,
    Else,
    With,
    Return,
    In,
    Range,
    Import,
    As,
    Pass,
    And,
    Or,
    Not,
    True,
    False,
    None_,
    // punctuation / operators
    At,        // @
    LParen,
    RParen,
    LBracket,
    RBracket,
    Colon,
    Comma,
    Dot,
    Assign,    // =
    PlusEq,
    MinusEq,
    TimesEq,
    DivEq,
    Plus,
    Minus,
    Star,
    StarStar,
    Slash,
    SlashSlash,
    Percent,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    NotEq,
    Arrow, // ->
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "identifier '{s}'"),
            Tok::Int(v) => write!(f, "int {v}"),
            Tok::Float(v) => write!(f, "float {v}"),
            Tok::Str(s) => write!(f, "string {s:?}"),
            other => write!(f, "{other:?}"),
        }
    }
}

/// A token plus its source line (1-based) for diagnostics.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

/// Lexing error with location.
#[derive(Clone, Debug)]
pub struct LexError {
    pub message: String,
    pub line: usize,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for LexError {}

pub fn lex(source: &str) -> Result<Vec<Token>, LexError> {
    let mut tokens = Vec::new();
    let mut indents: Vec<usize> = vec![0];
    let mut bracket_depth = 0usize;
    let mut pending_line = false; // have we emitted any token on this logical line?

    for (line_no, raw_line) in source.lines().enumerate() {
        let line_no = line_no + 1;
        let line = raw_line;
        // Strip comments outside of strings (the DSL has no '#' in strings
        // we care about; keep it simple but respect quotes).
        let code = strip_comment(line);
        if bracket_depth == 0 {
            if code.trim().is_empty() {
                continue; // blank or comment-only line
            }
            // indentation handling
            let indent = code.len() - code.trim_start_matches(' ').len();
            if code.as_bytes().first() == Some(&b'\t') {
                return Err(LexError { message: "tabs are not allowed for indentation".into(), line: line_no });
            }
            if pending_line {
                tokens.push(Token { tok: Tok::Newline, line: line_no });
            }
            let current = *indents.last().unwrap();
            if indent > current {
                indents.push(indent);
                tokens.push(Token { tok: Tok::Indent, line: line_no });
            } else if indent < current {
                while *indents.last().unwrap() > indent {
                    indents.pop();
                    tokens.push(Token { tok: Tok::Dedent, line: line_no });
                }
                if *indents.last().unwrap() != indent {
                    return Err(LexError {
                        message: format!("unindent to {indent} does not match any outer level"),
                        line: line_no,
                    });
                }
            }
        }
        lex_line(code.trim_start_matches(' '), line_no, &mut tokens, &mut bracket_depth)?;
        pending_line = true;
    }
    if pending_line {
        tokens.push(Token { tok: Tok::Newline, line: source.lines().count() });
    }
    while indents.len() > 1 {
        indents.pop();
        tokens.push(Token { tok: Tok::Dedent, line: source.lines().count() });
    }
    tokens.push(Token { tok: Tok::Eof, line: source.lines().count() });
    Ok(tokens)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (in_str, c) {
            (None, '#') => return &line[..i],
            (None, '"') | (None, '\'') => in_str = Some(c),
            (Some(q), c) if c == q => in_str = None,
            _ => {}
        }
    }
    line
}

fn keyword(word: &str) -> Option<Tok> {
    Some(match word {
        "def" => Tok::Def,
        "for" => Tok::For,
        "while" => Tok::While,
        "if" => Tok::If,
        "elif" => Tok::Elif,
        "else" => Tok::Else,
        "with" => Tok::With,
        "return" => Tok::Return,
        "in" => Tok::In,
        "range" => Tok::Range,
        "import" => Tok::Import,
        "as" => Tok::As,
        "pass" => Tok::Pass,
        "and" => Tok::And,
        "or" => Tok::Or,
        "not" => Tok::Not,
        "True" => Tok::True,
        "False" => Tok::False,
        "None" => Tok::None_,
        _ => return None,
    })
}

fn lex_line(
    code: &str,
    line_no: usize,
    tokens: &mut Vec<Token>,
    bracket_depth: &mut usize,
) -> Result<(), LexError> {
    let bytes = code.as_bytes();
    let mut i = 0usize;
    let push = |tokens: &mut Vec<Token>, tok: Tok| tokens.push(Token { tok, line: line_no });
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            ' ' => {
                i += 1;
            }
            '0'..='9' => {
                let start = i;
                let mut is_float = false;
                while i < bytes.len()
                    && (bytes[i].is_ascii_digit()
                        || bytes[i] == b'.'
                        || bytes[i] == b'e'
                        || bytes[i] == b'E'
                        || ((bytes[i] == b'+' || bytes[i] == b'-')
                            && i > start
                            && (bytes[i - 1] == b'e' || bytes[i - 1] == b'E')))
                {
                    if bytes[i] == b'.' || bytes[i] == b'e' || bytes[i] == b'E' {
                        is_float = true;
                    }
                    i += 1;
                }
                let text = &code[start..i];
                if is_float {
                    let v: f64 = text.parse().map_err(|_| LexError {
                        message: format!("bad float literal '{text}'"),
                        line: line_no,
                    })?;
                    push(tokens, Tok::Float(v));
                } else {
                    let v: i64 = text.parse().map_err(|_| LexError {
                        message: format!("bad int literal '{text}'"),
                        line: line_no,
                    })?;
                    push(tokens, Tok::Int(v));
                }
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let start = i;
                while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                    i += 1;
                }
                let word = &code[start..i];
                match keyword(word) {
                    Some(k) => push(tokens, k),
                    None => push(tokens, Tok::Ident(word.to_string())),
                }
            }
            '"' | '\'' => {
                let quote = c;
                let start = i + 1;
                i += 1;
                while i < bytes.len() && bytes[i] as char != quote {
                    i += 1;
                }
                if i >= bytes.len() {
                    return Err(LexError { message: "unterminated string".into(), line: line_no });
                }
                push(tokens, Tok::Str(code[start..i].to_string()));
                i += 1;
            }
            '@' => {
                push(tokens, Tok::At);
                i += 1;
            }
            '(' => {
                *bracket_depth += 1;
                push(tokens, Tok::LParen);
                i += 1;
            }
            ')' => {
                *bracket_depth = bracket_depth.saturating_sub(1);
                push(tokens, Tok::RParen);
                i += 1;
            }
            '[' => {
                *bracket_depth += 1;
                push(tokens, Tok::LBracket);
                i += 1;
            }
            ']' => {
                *bracket_depth = bracket_depth.saturating_sub(1);
                push(tokens, Tok::RBracket);
                i += 1;
            }
            ':' => {
                push(tokens, Tok::Colon);
                i += 1;
            }
            ',' => {
                push(tokens, Tok::Comma);
                i += 1;
            }
            '.' => {
                push(tokens, Tok::Dot);
                i += 1;
            }
            '+' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::PlusEq);
                    i += 2;
                } else {
                    push(tokens, Tok::Plus);
                    i += 1;
                }
            }
            '-' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::MinusEq);
                    i += 2;
                } else if bytes.get(i + 1) == Some(&b'>') {
                    push(tokens, Tok::Arrow);
                    i += 2;
                } else {
                    push(tokens, Tok::Minus);
                    i += 1;
                }
            }
            '*' => {
                if bytes.get(i + 1) == Some(&b'*') {
                    push(tokens, Tok::StarStar);
                    i += 2;
                } else if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::TimesEq);
                    i += 2;
                } else {
                    push(tokens, Tok::Star);
                    i += 1;
                }
            }
            '/' => {
                if bytes.get(i + 1) == Some(&b'/') {
                    push(tokens, Tok::SlashSlash);
                    i += 2;
                } else if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::DivEq);
                    i += 2;
                } else {
                    push(tokens, Tok::Slash);
                    i += 1;
                }
            }
            '%' => {
                push(tokens, Tok::Percent);
                i += 1;
            }
            '<' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::Le);
                    i += 2;
                } else {
                    push(tokens, Tok::Lt);
                    i += 1;
                }
            }
            '>' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::Ge);
                    i += 2;
                } else {
                    push(tokens, Tok::Gt);
                    i += 1;
                }
            }
            '=' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::EqEq);
                    i += 2;
                } else {
                    push(tokens, Tok::Assign);
                    i += 1;
                }
            }
            '!' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    push(tokens, Tok::NotEq);
                    i += 2;
                } else {
                    return Err(LexError { message: "unexpected '!'".into(), line: line_no });
                }
            }
            '\t' => {
                i += 1; // interior tabs treated as spaces
            }
            other => {
                return Err(LexError {
                    message: format!("unexpected character '{other}'"),
                    line: line_no,
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lex_simple_assignment() {
        let toks = kinds("x = 1 + 2.5");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("x".into()),
                Tok::Assign,
                Tok::Int(1),
                Tok::Plus,
                Tok::Float(2.5),
                Tok::Newline,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn indent_dedent_pairs() {
        let src = "def f():\n    x = 1\n    y = 2\nz = 3\n";
        let toks = kinds(src);
        let indents = toks.iter().filter(|t| **t == Tok::Indent).count();
        let dedents = toks.iter().filter(|t| **t == Tok::Dedent).count();
        assert_eq!(indents, 1);
        assert_eq!(dedents, 1);
    }

    #[test]
    fn nested_indentation() {
        let src = "def f():\n    for i in range(3):\n        x = i\n";
        let toks = kinds(src);
        let indents = toks.iter().filter(|t| **t == Tok::Indent).count();
        let dedents = toks.iter().filter(|t| **t == Tok::Dedent).count();
        assert_eq!(indents, 2);
        assert_eq!(dedents, 2); // closed at EOF
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let src = "# header\n\nx = 1  # trailing\n\n";
        let toks = kinds(src);
        assert_eq!(toks, vec![Tok::Ident("x".into()), Tok::Assign, Tok::Int(1), Tok::Newline, Tok::Eof]);
    }

    #[test]
    fn bracket_continuation_joins_lines() {
        let src = "x = f(1,\n      2)\ny = 3\n";
        let toks = kinds(src);
        // only two logical lines -> two Newlines
        assert_eq!(toks.iter().filter(|t| **t == Tok::Newline).count(), 2);
        assert_eq!(toks.iter().filter(|t| **t == Tok::Indent).count(), 0);
    }

    #[test]
    fn operators() {
        let toks = kinds("a // b % c ** d != e <= f");
        assert!(toks.contains(&Tok::SlashSlash));
        assert!(toks.contains(&Tok::Percent));
        assert!(toks.contains(&Tok::StarStar));
        assert!(toks.contains(&Tok::NotEq));
        assert!(toks.contains(&Tok::Le));
    }

    #[test]
    fn decorator_and_subscript() {
        let toks = kinds("@ascend_kernel\ndef k():\n    pass\n");
        assert_eq!(toks[0], Tok::At);
        assert_eq!(toks[1], Tok::Ident("ascend_kernel".into()));
    }

    #[test]
    fn float_with_exponent() {
        let toks = kinds("x = -1e30");
        assert!(toks.contains(&Tok::Float(1e30)));
        assert!(toks.contains(&Tok::Minus));
    }

    #[test]
    fn bad_unindent_is_error() {
        let src = "def f():\n    x = 1\n  y = 2\n";
        assert!(lex(src).is_err());
    }

    #[test]
    fn unterminated_string_is_error() {
        assert!(lex("x = \"abc").is_err());
    }

    #[test]
    fn line_numbers_recorded() {
        let toks = lex("a = 1\nb = 2\n").unwrap();
        let b = toks.iter().find(|t| t.tok == Tok::Ident("b".into())).unwrap();
        assert_eq!(b.line, 2);
    }
}
