//! The AscendCraft DSL frontend (paper §3).
//!
//! The DSL is a restricted, indentation-sensitive Python subset in the style
//! of the paper's Figure 2: a program is a `@ascend_kernel` kernel function
//! plus a host function. The kernel expresses on-chip behaviour — explicit
//! `tl.alloc_ub` buffer allocation and staged `with tl.copyin(): /
//! tl.compute(): / tl.copyout():` blocks — while the host expresses global
//! planning: core partitioning, tiling strategy, and the launch
//! `kernel[n_cores](...)`.
//!
//! Pipeline: [`lexer`] → [`parser`] → [`ast`] → [`validate`] (staging rules,
//! explicit allocation, no implicit aliasing) → consumed by
//! `transpile` (lowering to AscendC) and `synth` (example library).

pub mod ast;
pub mod lexer;
pub mod parser;
pub mod printer;
pub mod validate;

pub use ast::{DslProgram, HostFn, KernelFn};
pub use parser::parse_program;
pub use validate::{validate_program, DslDiagnostic};

/// Parse + semantically validate DSL source. This is the "does the DSL
/// program even make sense" gate that the synthesizer's output must pass
/// before transcompilation begins.
pub fn frontend(source: &str) -> Result<DslProgram, Vec<DslDiagnostic>> {
    let program = parser::parse_program(source).map_err(|e| {
        vec![DslDiagnostic {
            code: "P000".into(),
            message: e.to_string(),
            line: e.line,
            severity: crate::diag::Severity::Error,
        }]
    })?;
    let diags = validate::validate_program(&program);
    if diags.is_empty() {
        Ok(program)
    } else {
        Err(diags)
    }
}
