//! Pretty-printer for DSL programs: renders an AST back to canonical DSL
//! source. Used by the CLI (`ascendcraft gen --emit-dsl`), by the expert
//! example library's self-checks (every example must round-trip through
//! parse → print → parse), and by failure reports.

use super::ast::*;
use std::fmt::Write as _;

pub fn print_program(p: &DslProgram) -> String {
    let mut out = String::from("import tile.language as tl\n");
    for k in p.kernels() {
        out.push('\n');
        print_kernel(&mut out, k);
    }
    out.push('\n');
    print_host(&mut out, &p.host);
    out
}

fn print_kernel(out: &mut String, k: &KernelFn) {
    let params: Vec<&str> = k.params.iter().map(|p| p.name.as_str()).collect();
    let _ = writeln!(out, "@ascend_kernel");
    let _ = writeln!(out, "def {}({}):", k.name, params.join(", "));
    print_stmts(out, &k.body, 1);
}

fn print_host(out: &mut String, h: &HostFn) {
    let params: Vec<&str> = h.params.iter().map(|p| p.name.as_str()).collect();
    let _ = writeln!(out, "def {}({}):", h.name, params.join(", "));
    print_stmts(out, &h.body, 1);
}

fn indent(out: &mut String, level: usize) {
    for _ in 0..level {
        out.push_str("    ");
    }
}

fn print_stmts(out: &mut String, stmts: &[Stmt], level: usize) {
    for s in stmts {
        print_stmt(out, s, level);
    }
}

fn print_stmt(out: &mut String, stmt: &Stmt, level: usize) {
    indent(out, level);
    match stmt {
        Stmt::Assign { target, value, .. } => {
            let _ = writeln!(out, "{target} = {}", print_expr(value));
        }
        Stmt::AugAssign { target, op, value, .. } => {
            let sym = match op {
                BinOp::Add => "+=",
                BinOp::Sub => "-=",
                BinOp::Mul => "*=",
                BinOp::Div => "/=",
                _ => "=",
            };
            let _ = writeln!(out, "{target} {sym} {}", print_expr(value));
        }
        Stmt::For { var, start, end, step, body, .. } => {
            let range = match (start, step) {
                (Expr::Int(0), None) => format!("range({})", print_expr(end)),
                (_, None) => format!("range({}, {})", print_expr(start), print_expr(end)),
                (_, Some(st)) => {
                    format!("range({}, {}, {})", print_expr(start), print_expr(end), print_expr(st))
                }
            };
            let _ = writeln!(out, "for {var} in {range}:");
            print_stmts(out, body, level + 1);
        }
        Stmt::While { cond, body, .. } => {
            let _ = writeln!(out, "while {}:", print_expr(cond));
            print_stmts(out, body, level + 1);
        }
        Stmt::If { cond, then, orelse, .. } => {
            let _ = writeln!(out, "if {}:", print_expr(cond));
            print_stmts(out, then, level + 1);
            if !orelse.is_empty() {
                indent(out, level);
                let _ = writeln!(out, "else:");
                print_stmts(out, orelse, level + 1);
            }
        }
        Stmt::WithStage { stage, body, .. } => {
            let _ = writeln!(out, "with tl.{}():", stage.name());
            print_stmts(out, body, level + 1);
        }
        Stmt::ExprStmt { expr, .. } => {
            let _ = writeln!(out, "{}", print_expr(expr));
        }
        Stmt::Launch { kernel, grid, args, .. } => {
            let args: Vec<String> = args.iter().map(print_expr).collect();
            let _ = writeln!(out, "{kernel}[{}]({})", print_expr(grid), args.join(", "));
        }
        Stmt::Pass { .. } => {
            let _ = writeln!(out, "pass");
        }
        Stmt::Return { value, .. } => match value {
            Some(v) => {
                let _ = writeln!(out, "return {}", print_expr(v));
            }
            None => {
                let _ = writeln!(out, "return");
            }
        },
    }
}

fn binop_sym(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "+",
        BinOp::Sub => "-",
        BinOp::Mul => "*",
        BinOp::Div => "/",
        BinOp::FloorDiv => "//",
        BinOp::Mod => "%",
        BinOp::Pow => "**",
        BinOp::Lt => "<",
        BinOp::Le => "<=",
        BinOp::Gt => ">",
        BinOp::Ge => ">=",
        BinOp::Eq => "==",
        BinOp::Ne => "!=",
        BinOp::And => "and",
        BinOp::Or => "or",
    }
}

fn prec(op: BinOp) -> u8 {
    match op {
        BinOp::Or => 1,
        BinOp::And => 2,
        BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne => 3,
        BinOp::Add | BinOp::Sub => 4,
        BinOp::Mul | BinOp::Div | BinOp::FloorDiv | BinOp::Mod => 5,
        BinOp::Pow => 7,
    }
}

pub fn print_expr(e: &Expr) -> String {
    print_expr_prec(e, 0)
}

fn print_expr_prec(e: &Expr, parent: u8) -> String {
    match e {
        Expr::Int(v) => v.to_string(),
        Expr::Float(v) => {
            if v.fract() == 0.0 && v.abs() < 1e16 {
                format!("{:.1}", v)
            } else if v.abs() >= 1e16 || (*v != 0.0 && v.abs() < 1e-4) {
                // scientific notation so the literal survives re-lexing
                format!("{:e}", v)
            } else {
                format!("{v}")
            }
        }
        Expr::Bool(b) => (if *b { "True" } else { "False" }).to_string(),
        Expr::Str(s) => format!("\"{s}\""),
        Expr::Name(n) => n.clone(),
        Expr::Bin(op, a, b) => {
            let p = prec(*op);
            let s = format!(
                "{} {} {}",
                print_expr_prec(a, p),
                binop_sym(*op),
                print_expr_prec(b, p + 1)
            );
            if p < parent {
                format!("({s})")
            } else {
                s
            }
        }
        Expr::Un(UnOp::Neg, a) => format!("-{}", print_expr_prec(a, 6)),
        Expr::Un(UnOp::Not, a) => format!("not {}", print_expr_prec(a, 3)),
        Expr::Call { func, args, kwargs } => {
            let mut parts: Vec<String> = args.iter().map(print_expr).collect();
            for (k, v) in kwargs {
                parts.push(format!("{k}={}", print_expr(v)));
            }
            format!("{func}({})", parts.join(", "))
        }
        Expr::Index { base, index } => {
            format!("{}[{}]", print_expr_prec(base, 8), print_expr(index))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_program;

    const SRC: &str = "
@ascend_kernel
def k(x_ptr, y_ptr, n, tile_len, n_tiles):
    pid = tl.program_id(0)
    in_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    acc = -1e30
    for t in range(n_tiles):
        off = pid * n + t * tile_len
        with tl.copyin():
            tl.load(x_ptr + off, in_ub, tile_len)
        with tl.compute():
            tl.vexp(in_ub, in_ub, tile_len)
        with tl.copyout():
            tl.store(y_ptr + off, in_ub, tile_len)
    if n > 0:
        acc += 1
    else:
        acc = 0

def h(x, y):
    n = x.shape[0]
    k[8](x, y, n, 1024, (n + 1023) // 1024)
";

    #[test]
    fn roundtrip_is_stable() {
        let p1 = parse_program(SRC).unwrap();
        let printed1 = print_program(&p1);
        let p2 = parse_program(&printed1).unwrap();
        let printed2 = print_program(&p2);
        assert_eq!(printed1, printed2);
    }

    #[test]
    fn roundtrip_preserves_ast() {
        // ASTs are compared via their canonical printed form, which is
        // line-number-insensitive (printing normalizes locations).
        let p1 = parse_program(SRC).unwrap();
        let p2 = parse_program(&print_program(&p1)).unwrap();
        assert_eq!(print_program(&p1), print_program(&p2));
        assert_eq!(p1.kernel.name, p2.kernel.name);
        assert_eq!(p1.kernel.params, p2.kernel.params);
    }

    #[test]
    fn parenthesization_preserves_precedence() {
        let e = Expr::Bin(
            BinOp::Mul,
            Box::new(Expr::Bin(BinOp::Add, Box::new(Expr::name("a")), Box::new(Expr::name("b")))),
            Box::new(Expr::name("c")),
        );
        assert_eq!(print_expr(&e), "(a + b) * c");
    }

    #[test]
    fn kwargs_printed() {
        let e = Expr::Call {
            func: "tl.alloc_ub".into(),
            args: vec![Expr::Int(64)],
            kwargs: vec![("dtype".into(), Expr::name("tl.float16"))],
        };
        assert_eq!(print_expr(&e), "tl.alloc_ub(64, dtype=tl.float16)");
    }

    #[test]
    fn float_formatting_reparses() {
        let e = Expr::Float(2.0);
        assert_eq!(print_expr(&e), "2.0");
        let e = Expr::Float(-1e30);
        assert_eq!(print_expr(&e), "-1e30");
    }
}
