//! Semantic validation of DSL programs — the language rules from paper §3:
//!
//! * **Staging rules.** `tl.load` only inside `copyin`, `tl.store` only
//!   inside `copyout`, vector/reduce compute primitives only inside
//!   `compute`; stages may not nest; scalar bookkeeping is allowed anywhere.
//! * **Explicit allocation.** Every buffer used by load/store/compute must
//!   come from `tl.alloc_ub` / `tl.alloc_l1` in the same kernel; allocation
//!   must happen outside stage blocks and outside loops (on-chip buffers are
//!   a static resource plan, not a dynamic heap).
//! * **No implicit aliasing.** A buffer name is assigned exactly once.
//! * **Launch discipline.** The host must launch every kernel exactly once
//!   per program point with an argument count matching the kernel signature.
//!
//! Diagnostics carry stable codes (`D1xx` staging, `D2xx` buffers, `D3xx`
//! host) so the synthesizer's repair engine can pattern-match them.

use super::ast::*;
use crate::diag::Severity;
use std::collections::{HashMap, HashSet};

/// A validation diagnostic. `line` is 1-based source line. Converts into
/// the pipeline-level [`crate::coordinator::stage::Diagnostic`] (stage
/// `frontend`) via `From`, keeping code and line.
#[derive(Clone, Debug, PartialEq)]
pub struct DslDiagnostic {
    pub code: String,
    pub message: String,
    pub line: usize,
    /// Every frontend rule is currently fatal; the field keeps the DSL
    /// validator on the same severity vocabulary as the other checkers.
    pub severity: Severity,
}

impl DslDiagnostic {
    fn new(code: &str, line: usize, message: String) -> DslDiagnostic {
        DslDiagnostic { code: code.to_string(), message, line, severity: Severity::Error }
    }
}

impl std::fmt::Display for DslDiagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} (line {}): {}", self.code, self.line, self.message)
    }
}

/// Primitives legal only in a given stage. Everything else (`tl.program_id`,
/// `tl.max`, `tl.extract_scalar`, arithmetic) is stage-neutral scalar code.
fn required_stage(func: &str) -> Option<Stage> {
    match func {
        "tl.load" => Some(Stage::CopyIn),
        "tl.store" => Some(Stage::CopyOut),
        _ if is_compute_primitive(func) => Some(Stage::Compute),
        _ => None,
    }
}

/// Vector/cube/reduce primitives that execute on compute units.
pub fn is_compute_primitive(func: &str) -> bool {
    matches!(
        func,
        "tl.vadd"
            | "tl.vsub"
            | "tl.vmul"
            | "tl.vdiv"
            | "tl.vmax"
            | "tl.vmin"
            | "tl.vexp"
            | "tl.vlog"
            | "tl.vabs"
            | "tl.vsqrt"
            | "tl.vrsqrt"
            | "tl.vrec"
            | "tl.vneg"
            | "tl.vtanh"
            | "tl.vrelu"
            | "tl.vsign"
            | "tl.vfloor"
            | "tl.adds"
            | "tl.muls"
            | "tl.maxs"
            | "tl.mins"
            | "tl.vcopy"
            | "tl.vselect_ge"
            | "tl.vcmp_gt"
            | "tl.reduce_sum"
            | "tl.reduce_max"
            | "tl.reduce_min"
            | "tl.cumsum"
            | "tl.cumprod"
            | "tl.memset"
            | "tl.cast"
            | "tl.matmul"
            | "tl.vpow"
    )
}

/// All known `tl.` functions (anything else is an unknown primitive).
fn is_known_tl(func: &str) -> bool {
    is_compute_primitive(func)
        || matches!(
            func,
            "tl.load"
                | "tl.store"
                | "tl.alloc_ub"
                | "tl.alloc_l1"
                | "tl.program_id"
                | "tl.num_programs"
                | "tl.arange"
                | "tl.max"
                | "tl.min"
                | "tl.extract_scalar"
                | "tl.insert_scalar"
                | "tl.sync_all"
                | "tl.exp"
                | "tl.log"
                | "tl.sqrt"
                | "tl.abs"
        )
}

pub fn validate_program(program: &DslProgram) -> Vec<DslDiagnostic> {
    let mut diags = Vec::new();
    for kernel in program.kernels() {
        validate_kernel(kernel, &mut diags);
    }
    validate_host(program, &mut diags);
    diags
}

struct KernelCtx<'a> {
    kernel: &'a KernelFn,
    buffers: HashMap<String, AllocKind>,
    assigned: HashSet<String>,
}

fn validate_kernel(kernel: &KernelFn, diags: &mut Vec<DslDiagnostic>) {
    let mut ctx = KernelCtx {
        kernel,
        buffers: HashMap::new(),
        assigned: kernel.params.iter().map(|p| p.name.clone()).collect(),
    };
    // Collect buffer allocations first (they must be top-level).
    collect_allocs(&kernel.body, true, false, &mut ctx, diags);
    // Then walk with stage context.
    walk_stmts(&kernel.body, None, &mut ctx, diags);
}

fn collect_allocs(
    stmts: &[Stmt],
    top_level: bool,
    in_stage: bool,
    ctx: &mut KernelCtx,
    diags: &mut Vec<DslDiagnostic>,
) {
    for stmt in stmts {
        match stmt {
            Stmt::Assign { target, value, line } => {
                if let Some((kind, _, _)) = as_alloc(value) {
                    if in_stage {
                        diags.push(DslDiagnostic::new(
                            "D201",
                            *line,
                            format!("buffer '{target}' allocated inside a stage block; on-chip buffers must be planned at kernel top level"),
                        ));
                    } else if !top_level {
                        diags.push(DslDiagnostic::new(
                            "D202",
                            *line,
                            format!("buffer '{target}' allocated inside a loop/branch; allocation must be static (kernel top level)"),
                        ));
                    }
                    if ctx.buffers.insert(target.clone(), kind).is_some() {
                        diags.push(DslDiagnostic::new(
                            "D203",
                            *line,
                            format!("buffer '{target}' allocated more than once (implicit aliasing is disallowed)"),
                        ));
                    }
                }
            }
            Stmt::For { body, .. } | Stmt::While { body, .. } => {
                collect_allocs(body, false, in_stage, ctx, diags)
            }
            Stmt::WithStage { body, .. } => collect_allocs(body, false, true, ctx, diags),
            Stmt::If { then, orelse, .. } => {
                collect_allocs(then, false, in_stage, ctx, diags);
                collect_allocs(orelse, false, in_stage, ctx, diags);
            }
            _ => {}
        }
    }
}

fn walk_stmts(
    stmts: &[Stmt],
    stage: Option<Stage>,
    ctx: &mut KernelCtx,
    diags: &mut Vec<DslDiagnostic>,
) {
    for stmt in stmts {
        match stmt {
            Stmt::WithStage { stage: s, body, line } => {
                if stage.is_some() {
                    diags.push(DslDiagnostic::new(
                        "D101",
                        *line,
                        format!("stage '{}' nested inside stage '{}'; stages must not nest", s.name(), stage.unwrap().name()),
                    ));
                }
                walk_stmts(body, Some(*s), ctx, diags);
            }
            Stmt::Assign { target, value, line } => {
                check_expr(value, stage, ctx, diags, *line);
                if !as_alloc(value).is_some() && ctx.buffers.contains_key(target) {
                    diags.push(DslDiagnostic::new(
                        "D204",
                        *line,
                        format!("buffer '{target}' reassigned to a non-buffer value (implicit aliasing)"),
                    ));
                }
                ctx.assigned.insert(target.clone());
            }
            Stmt::AugAssign { target, value, line, .. } => {
                check_expr(value, stage, ctx, diags, *line);
                if !ctx.assigned.contains(target) {
                    diags.push(DslDiagnostic::new(
                        "D301",
                        *line,
                        format!("augmented assignment to undefined variable '{target}'"),
                    ));
                }
            }
            Stmt::For { var, start, end, step, body, line } => {
                check_expr(start, stage, ctx, diags, *line);
                check_expr(end, stage, ctx, diags, *line);
                if let Some(s) = step {
                    check_expr(s, stage, ctx, diags, *line);
                }
                ctx.assigned.insert(var.clone());
                walk_stmts(body, stage, ctx, diags);
            }
            Stmt::While { cond, body, line } => {
                check_expr(cond, stage, ctx, diags, *line);
                walk_stmts(body, stage, ctx, diags);
            }
            Stmt::If { cond, then, orelse, line } => {
                check_expr(cond, stage, ctx, diags, *line);
                walk_stmts(then, stage, ctx, diags);
                walk_stmts(orelse, stage, ctx, diags);
            }
            Stmt::ExprStmt { expr, line } => check_expr(expr, stage, ctx, diags, *line),
            Stmt::Launch { line, .. } => {
                diags.push(DslDiagnostic::new(
                    "D102",
                    *line,
                    "kernel launch inside a kernel function (launches belong to the host)".into(),
                ));
            }
            Stmt::Pass { .. } | Stmt::Return { .. } => {}
        }
    }
}

fn check_expr(
    expr: &Expr,
    stage: Option<Stage>,
    ctx: &mut KernelCtx,
    diags: &mut Vec<DslDiagnostic>,
    line: usize,
) {
    expr.walk(&mut |e| {
        if let Expr::Call { func, args, .. } = e {
            if func.starts_with("tl.") && !is_known_tl(func) {
                diags.push(DslDiagnostic::new(
                    "D103",
                    line,
                    format!("unknown DSL primitive '{func}'"),
                ));
            }
            if let Some(required) = required_stage(func) {
                match stage {
                    Some(s) if s == required => {}
                    Some(s) => diags.push(DslDiagnostic::new(
                        "D104",
                        line,
                        format!("'{func}' requires stage '{}' but appears in stage '{}'", required.name(), s.name()),
                    )),
                    None => diags.push(DslDiagnostic::new(
                        "D105",
                        line,
                        format!("'{func}' requires stage '{}' but appears outside any stage block", required.name()),
                    )),
                }
            }
            // buffer arguments must be allocated
            for a in args {
                if let Expr::Name(n) = a {
                    if n.ends_with("_ub") || n.ends_with("_l1") {
                        if !ctx.buffers.contains_key(n)
                            && !ctx.kernel.params.iter().any(|p| &p.name == n)
                        {
                            diags.push(DslDiagnostic::new(
                                "D205",
                                line,
                                format!("buffer '{n}' used before allocation (tl.alloc_ub/tl.alloc_l1 required)"),
                            ));
                        }
                    }
                }
            }
        }
    });
}

fn validate_host(program: &DslProgram, diags: &mut Vec<DslDiagnostic>) {
    let host = &program.host;
    let mut launches: HashMap<String, usize> = HashMap::new();
    for stmt in &host.body {
        stmt.walk(&mut |s| {
            match s {
                Stmt::Launch { kernel, args, line, .. } => {
                    match program.kernel_by_name(kernel) {
                        None => diags.push(DslDiagnostic::new(
                            "D302",
                            *line,
                            format!("launch of unknown kernel '{kernel}'"),
                        )),
                        Some(k) => {
                            if args.len() != k.params.len() {
                                diags.push(DslDiagnostic::new(
                                    "D303",
                                    *line,
                                    format!(
                                        "kernel '{kernel}' expects {} arguments, launch passes {}",
                                        k.params.len(),
                                        args.len()
                                    ),
                                ));
                            }
                        }
                    }
                    *launches.entry(kernel.clone()).or_insert(0) += 1;
                }
                Stmt::WithStage { line, .. } => diags.push(DslDiagnostic::new(
                    "D304",
                    *line,
                    "stage blocks are kernel-only; host code cannot contain tl.copyin/compute/copyout".into(),
                )),
                _ => {}
            }
        });
    }
    for k in program.kernels() {
        if !launches.contains_key(k.name.as_str()) {
            diags.push(DslDiagnostic::new(
                "D305",
                host.line,
                format!("kernel '{}' is never launched by the host", k.name),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::parser::parse_program;

    fn diags_for(src: &str) -> Vec<DslDiagnostic> {
        validate_program(&parse_program(src).unwrap())
    }

    fn codes(src: &str) -> Vec<String> {
        diags_for(src).into_iter().map(|d| d.code).collect()
    }

    const OK_PROGRAM: &str = "
@ascend_kernel
def k(x_ptr, y_ptr, n, tile_len, n_tiles):
    pid = tl.program_id(0)
    in_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    out_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    for t in range(n_tiles):
        off = pid * n + t * tile_len
        with tl.copyin():
            tl.load(x_ptr + off, in_ub, tile_len)
        with tl.compute():
            tl.vexp(out_ub, in_ub, tile_len)
        with tl.copyout():
            tl.store(y_ptr + off, out_ub, tile_len)

def h(x, y):
    n = x.shape[0]
    n_cores = 8
    per = n // n_cores
    tile_len = 1024
    n_tiles = (per + tile_len - 1) // tile_len
    k[n_cores](x, y, per, tile_len, n_tiles)
";

    #[test]
    fn clean_program_has_no_diagnostics() {
        assert!(diags_for(OK_PROGRAM).is_empty(), "{:?}", diags_for(OK_PROGRAM));
    }

    #[test]
    fn load_outside_copyin_flagged() {
        let src = OK_PROGRAM.replace("with tl.copyin():\n            tl.load", "with tl.compute():\n            tl.load");
        assert!(codes(&src).contains(&"D104".to_string()));
    }

    #[test]
    fn compute_outside_stage_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, y_ptr, tile_len):
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    tl.vexp(a_ub, a_ub, tile_len)

def h(x, y):
    k[1](x, y, 128)
";
        assert!(codes(src).contains(&"D105".to_string()));
    }

    #[test]
    fn nested_stage_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    with tl.compute():
        with tl.copyin():
            tl.load(x_ptr, a_ub, tile_len)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D101".to_string()));
    }

    #[test]
    fn alloc_in_loop_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, n_tiles, tile_len):
    for t in range(n_tiles):
        a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)

def h(x):
    k[1](x, 4, 64)
";
        assert!(codes(src).contains(&"D202".to_string()));
    }

    #[test]
    fn double_alloc_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D203".to_string()));
    }

    #[test]
    fn unallocated_buffer_use_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    with tl.copyin():
        tl.load(x_ptr, ghost_ub, tile_len)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D205".to_string()));
    }

    #[test]
    fn unknown_primitive_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    with tl.compute():
        tl.vsoftmax(a_ub, a_ub, tile_len)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D103".to_string()));
    }

    #[test]
    fn launch_argument_mismatch_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, y_ptr, n):
    pid = tl.program_id(0)

def h(x, y):
    k[4](x, y)
";
        assert!(codes(src).contains(&"D303".to_string()));
    }

    #[test]
    fn unlaunched_kernel_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr):
    pid = tl.program_id(0)

def h(x):
    n = 1
";
        assert!(codes(src).contains(&"D305".to_string()));
    }

    #[test]
    fn launch_of_unknown_kernel_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr):
    pid = tl.program_id(0)

def h(x):
    k[1](x)
    other[1](x)
";
        assert!(codes(src).contains(&"D302".to_string()));
    }

    #[test]
    fn buffer_reassigned_to_scalar_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
    a_ub = 3

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D204".to_string()));
    }

    #[test]
    fn launch_inside_kernel_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    k[1](x_ptr, tile_len)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D102".to_string()));
    }

    #[test]
    fn alloc_inside_stage_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    with tl.copyin():
        a_ub = tl.alloc_ub(tile_len, dtype=tl.float32)
        tl.load(x_ptr, a_ub, tile_len)

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D201".to_string()));
    }

    #[test]
    fn augassign_of_undefined_name_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr, tile_len):
    acc += 1

def h(x):
    k[1](x, 64)
";
        assert!(codes(src).contains(&"D301".to_string()));
    }

    #[test]
    fn stage_block_in_host_flagged() {
        let src = "
@ascend_kernel
def k(x_ptr):
    pid = tl.program_id(0)

def h(x):
    k[1](x)
    with tl.copyin():
        pass
";
        assert!(codes(src).contains(&"D304".to_string()));
    }

    #[test]
    fn dsl_diagnostics_are_errors_on_the_shared_severity() {
        let d = &diags_for("
@ascend_kernel
def k(x_ptr):
    acc += 1

def h(x):
    k[1](x)
")[0];
        assert_eq!(d.severity, Severity::Error);
    }

    #[test]
    fn frontend_roundtrip_ok() {
        assert!(crate::dsl::frontend(OK_PROGRAM).is_ok());
    }
}
