//! The default backend: the Ascend NPU functional + timing simulator.
//!
//! `compile` is the AscendC structural validator (the Comp@1 gate) and
//! `execute` is `crate::sim::exec::simulate_owned` — exactly the calls the
//! pre-registry `CompileStage`/`SimulateStage` made inline, so results are
//! bit-identical to the unparameterized pipeline (enforced by
//! `tests/backend_api.rs`).

use super::{
    compile_with_validator, Backend, CompileReport, CompiledKernel, ExecOutput, BACKEND_ASCEND_SIM,
};
use crate::ascendc::AscProgram;
use crate::coordinator::stage::{Diagnostic, Session};
use crate::sim;
use crate::util::tensor::Tensor;
use std::collections::HashMap;

/// NPU simulator backend (`"ascend-sim"`): functional execution with the
/// per-unit timing model, producing Fastₓ cycles.
pub struct AscendSimBackend;

impl Backend for AscendSimBackend {
    fn name(&self) -> &'static str {
        BACKEND_ASCEND_SIM
    }

    fn compile(&self, session: &Session, program: AscProgram) -> CompileReport {
        compile_with_validator(BACKEND_ASCEND_SIM, session, program)
    }

    fn execute(
        &self,
        kernel: &CompiledKernel,
        inputs: HashMap<String, Tensor>,
        cores: usize,
    ) -> Result<ExecOutput, Diagnostic> {
        sim::exec::simulate_owned(&kernel.program, inputs, cores)
            .map(|o| ExecOutput { tensors: o.tensors, cycles: Some(o.timing.total_cycles) })
            .map_err(Diagnostic::from)
    }
}
