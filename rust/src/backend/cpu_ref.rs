//! The CPU-reference backend: functional execution of the transpiled
//! AscendC program directly on the shared op-kernel layer
//! (`crate::util::kernels`), with **no timing model** — no per-unit
//! timelines, no queue-slot clocks, no cycle accounting.
//!
//! This is the fast Pass@1 triage path: it answers "does the generated
//! kernel compute the right numbers" without paying for the NPU
//! simulation that prices it. Correctness verdicts agree with
//! [`super::AscendSimBackend`] by construction — the compile gate is the
//! same validator, the host evaluation is shared
//! ([`crate::sim::host::eval_host`]), scalar semantics come from the same
//! [`crate::sim::exec::eval_kernel_scalar`], and the data loops are the
//! same `util::kernels` the simulator runs — and the differential test in
//! `tests/backend_api.rs` enforces it over the whole default suite.
//!
//! Because there is no timing model, [`ExecOutput::cycles`] is `None`:
//! cpu-ref tasks have no Fastₓ speedup (functional triage only).
//!
//! Speed comes from the kernel layer itself: cpu-ref inherits the tiled/
//! packed `matmul_acc` and the pool-parallel elementwise/reduction splits
//! (bit-identical at any `--threads` setting), which is what keeps this
//! triage path cheap on large shapes.

use super::{
    compile_with_validator, Backend, CompileReport, CompiledKernel, ExecOutput, BACKEND_CPU_REF,
};
use crate::ascendc::ir::*;
use crate::coordinator::stage::{Diagnostic, Session};
use crate::sim::exec::{eval_kernel_scalar, vec_bin_op, vec_scalar_op, vec_un_op, STEP_LIMIT};
use crate::sim::host::eval_host;
use crate::sim::SimError;
use crate::util::kernels::{self, BinOp};
use crate::util::tensor::{f16_round_trip, DType, Tensor};
use std::collections::{HashMap, VecDeque};

/// Functional-only backend (`"cpu-ref"`): executes kernels on the host
/// with the shared op-kernel loops, skipping the NPU timing simulation.
pub struct CpuRefBackend;

impl Backend for CpuRefBackend {
    fn name(&self) -> &'static str {
        BACKEND_CPU_REF
    }

    fn compile(&self, session: &Session, program: AscProgram) -> CompileReport {
        // same compile gate as ascend-sim: what "compiles" is a property
        // of the AscendC program, not of the execution target
        compile_with_validator(BACKEND_CPU_REF, session, program)
    }

    fn execute(
        &self,
        kernel: &CompiledKernel,
        inputs: HashMap<String, Tensor>,
        _cores: usize,
    ) -> Result<ExecOutput, Diagnostic> {
        execute_functional(&kernel.program, inputs)
            .map(|tensors| ExecOutput { tensors, cycles: None })
            .map_err(Diagnostic::from)
    }
}

/// Execute a whole AscendC program functionally (host eval → launches →
/// blocks) over concrete host tensors. Errors use the same [`SimError`]
/// families as the simulator so diagnostic codes (`S101`–`S104`) agree
/// across backends.
pub fn execute_functional(
    program: &AscProgram,
    inputs: HashMap<String, Tensor>,
) -> Result<HashMap<String, Tensor>, SimError> {
    let mut gm = inputs;
    let host_eval = eval_host(&program.host, &gm)?;
    for (kernel_name, block_dim, args) in &host_eval.launches {
        let kernel = program
            .kernel(kernel_name)
            .ok_or_else(|| SimError::Host(format!("launch of unknown kernel '{kernel_name}'")))?;
        if kernel.globals.len() != args.len() {
            return Err(SimError::Host(format!(
                "kernel '{kernel_name}' binds {} globals, launch passes {}",
                kernel.globals.len(),
                args.len()
            )));
        }
        for block in 0..*block_dim {
            let mut interp = FuncInterp::new(kernel, &host_eval.tiling, args, &mut gm, block)?;
            for stmt in &kernel.init_body {
                interp.exec(stmt)?;
            }
            for stmt in &kernel.process_body {
                interp.exec(stmt)?;
            }
        }
    }
    Ok(gm)
}

/// On-chip buffer, functional view only (no readiness clocks).
struct FuncBuf {
    data: Vec<f32>,
    dtype: DType,
}

/// What a tensor name resolves to.
enum Resolved {
    Local(usize),
    Global(String),
}

#[derive(Clone, Copy)]
enum ScratchSel {
    A,
    B,
}

/// Per-block functional interpreter. Mirrors the simulator's
/// `sim::exec::Interp` statement by statement, minus every timing
/// concern: queues are plain FIFOs, `SyncAll` is a no-op, and `DataCopy`
/// is just a copy. The step limit uses the simulator's accounting so
/// runaway-kernel verdicts agree across backends.
struct FuncInterp<'a> {
    kernel: &'a AscKernel,
    bufs: Vec<FuncBuf>,
    /// local-tensor variable bindings -> slab index
    vars: HashMap<String, usize>,
    scalars: HashMap<String, f64>,
    queues: HashMap<String, VecDeque<usize>>,
    tbuf_idx: HashMap<String, usize>,
    gm: &'a mut HashMap<String, Tensor>,
    /// global member name -> host tensor key
    gm_bind: HashMap<String, String>,
    steps: u64,
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    scratch_c: Vec<f32>,
    /// freed tile buffers, pooled by capacity (same allocation-avoidance
    /// trick as the simulator's §Perf P1)
    free_bufs: Vec<Vec<f32>>,
}

impl<'a> FuncInterp<'a> {
    fn new(
        kernel: &'a AscKernel,
        tiling: &HashMap<String, i64>,
        args: &[String],
        gm: &'a mut HashMap<String, Tensor>,
        block: usize,
    ) -> Result<FuncInterp<'a>, SimError> {
        let mut scalars: HashMap<String, f64> = HashMap::new();
        for field in &kernel.tiling_fields {
            let v = tiling.get(field).ok_or_else(|| {
                SimError::Kernel(format!("tiling field '{field}' not computed by host"))
            })?;
            scalars.insert(field.clone(), *v as f64);
        }
        scalars.insert("__block_idx".into(), block as f64);

        let mut gm_bind = HashMap::new();
        for g in &kernel.globals {
            let arg = args.get(g.arg_index).ok_or_else(|| {
                SimError::Kernel(format!(
                    "global '{}' binds arg {} but launch has {} args",
                    g.name,
                    g.arg_index,
                    args.len()
                ))
            })?;
            gm_bind.insert(g.name.clone(), arg.clone());
        }

        let mut bufs = Vec::new();
        let mut tbuf_idx = HashMap::new();
        for t in &kernel.tbufs {
            bufs.push(FuncBuf { data: vec![0.0; t.capacity], dtype: t.dtype });
            tbuf_idx.insert(t.name.clone(), bufs.len() - 1);
        }

        let queues = kernel.queues.iter().map(|q| (q.name.clone(), VecDeque::new())).collect();

        Ok(FuncInterp {
            kernel,
            bufs,
            vars: HashMap::new(),
            scalars,
            queues,
            tbuf_idx,
            gm,
            gm_bind,
            steps: 0,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            scratch_c: Vec::new(),
            free_bufs: Vec::new(),
        })
    }

    fn step(&mut self, n: u64) -> Result<(), SimError> {
        self.steps += n;
        if self.steps > STEP_LIMIT {
            return Err(SimError::StepLimit);
        }
        Ok(())
    }

    fn kerr(&self, msg: String) -> SimError {
        SimError::Kernel(format!("[{}] {msg}", self.kernel.name))
    }

    fn eval(&self, e: &CExpr) -> Result<f64, SimError> {
        eval_kernel_scalar(&self.scalars, e).map_err(|m| self.kerr(m))
    }

    fn eval_usize(&self, e: &CExpr, what: &str) -> Result<usize, SimError> {
        let v = self.eval(e)?;
        if v < 0.0 || !v.is_finite() {
            return Err(self.kerr(format!("{what} evaluated to invalid value {v}")));
        }
        Ok(v as usize)
    }

    fn resolve(&self, name: &str) -> Result<Resolved, SimError> {
        if let Some(&idx) = self.vars.get(name) {
            return Ok(Resolved::Local(idx));
        }
        if let Some(&idx) = self.tbuf_idx.get(name) {
            return Ok(Resolved::Local(idx));
        }
        if let Some(host_key) = self.gm_bind.get(name) {
            return Ok(Resolved::Global(host_key.clone()));
        }
        Err(self.kerr(format!("tensor '{name}' is not bound")))
    }

    /// Read `count` elements at `r` into the selected scratch buffer.
    fn read_into(&mut self, r: &TensorRef, count: usize, which: ScratchSel) -> Result<(), SimError> {
        let off = self.eval_usize(&r.offset, "offset")?;
        let slice: &[f32] = match self.resolve(&r.name)? {
            Resolved::Local(idx) => {
                let buf = &self.bufs[idx];
                if off + count > buf.data.len() {
                    return Err(SimError::Oob(format!(
                        "read of {count} @ {off} from local '{}' (capacity {})",
                        r.name,
                        buf.data.len()
                    )));
                }
                &buf.data[off..off + count]
            }
            Resolved::Global(key) => {
                let t = &self.gm[&key];
                if off + count > t.data.len() {
                    return Err(SimError::Oob(format!(
                        "read of {count} @ {off} from global '{}' (size {})",
                        r.name,
                        t.data.len()
                    )));
                }
                &t.data[off..off + count]
            }
        };
        match which {
            ScratchSel::A => {
                self.scratch_a.clear();
                self.scratch_a.extend_from_slice(slice);
            }
            ScratchSel::B => {
                self.scratch_b.clear();
                self.scratch_b.extend_from_slice(slice);
            }
        }
        Ok(())
    }

    /// Write `values` to `r` (local or global), quantizing through f16
    /// when the destination buffer is half precision — identical numeric
    /// effect to the simulator's writes.
    fn write_from(&mut self, r: &TensorRef, values: &[f32]) -> Result<(), SimError> {
        let off = self.eval_usize(&r.offset, "offset")?;
        match self.resolve(&r.name)? {
            Resolved::Local(idx) => {
                let buf = &mut self.bufs[idx];
                if off + values.len() > buf.data.len() {
                    return Err(SimError::Oob(format!(
                        "write of {} @ {off} into local '{}' (capacity {})",
                        values.len(),
                        r.name,
                        buf.data.len()
                    )));
                }
                if buf.dtype == DType::F16 {
                    for (d, &v) in buf.data[off..off + values.len()].iter_mut().zip(values) {
                        *d = f16_round_trip(v);
                    }
                } else {
                    buf.data[off..off + values.len()].copy_from_slice(values);
                }
            }
            Resolved::Global(key) => {
                let t = self.gm.get_mut(&key).unwrap();
                if off + values.len() > t.data.len() {
                    return Err(SimError::Oob(format!(
                        "write of {} @ {off} into global '{}' (size {})",
                        values.len(),
                        r.name,
                        t.data.len()
                    )));
                }
                if t.dtype == DType::F16 {
                    for (d, &v) in t.data[off..off + values.len()].iter_mut().zip(values) {
                        *d = f16_round_trip(v);
                    }
                } else {
                    t.data[off..off + values.len()].copy_from_slice(values);
                }
            }
        }
        Ok(())
    }

    fn exec(&mut self, stmt: &CStmt) -> Result<(), SimError> {
        self.step(1)?;
        match stmt {
            CStmt::Comment(_) => {}
            CStmt::DeclAssign { name, value } | CStmt::Assign { name, value } => {
                let v = self.eval(value)?;
                self.scalars.insert(name.clone(), v);
            }
            CStmt::AllocTensor { queue, var } => {
                let qdecl = self
                    .kernel
                    .queue(queue)
                    .ok_or_else(|| self.kerr(format!("AllocTensor on unknown queue '{queue}'")))?;
                let (capacity, dtype) = (qdecl.capacity, qdecl.dtype);
                let data = match self.free_bufs.iter().position(|b| b.len() == capacity) {
                    Some(i) => self.free_bufs.swap_remove(i),
                    None => vec![0.0; capacity],
                };
                self.bufs.push(FuncBuf { data, dtype });
                self.vars.insert(var.clone(), self.bufs.len() - 1);
            }
            CStmt::EnQue { queue, var } => {
                let idx = *self
                    .vars
                    .get(var)
                    .ok_or_else(|| self.kerr(format!("EnQue of unbound tensor '{var}'")))?;
                self.vars.remove(var);
                let q = self
                    .queues
                    .get_mut(queue)
                    .ok_or_else(|| SimError::Kernel(format!("EnQue on unknown queue '{queue}'")))?;
                q.push_back(idx);
            }
            CStmt::DeQue { queue, var } => {
                let q = self
                    .queues
                    .get_mut(queue)
                    .ok_or_else(|| SimError::Kernel(format!("DeQue on unknown queue '{queue}'")))?;
                let idx = q.pop_front().ok_or_else(|| {
                    SimError::Kernel(format!(
                        "[{}] DeQue on empty queue '{queue}' (pipeline deadlock)",
                        self.kernel.name
                    ))
                })?;
                self.vars.insert(var.clone(), idx);
            }
            CStmt::FreeTensor { queue, var } => {
                let idx = *self
                    .vars
                    .get(var)
                    .ok_or_else(|| self.kerr(format!("FreeTensor of unbound tensor '{var}'")))?;
                self.vars.remove(var);
                if !self.queues.contains_key(queue) {
                    return Err(SimError::Kernel(format!(
                        "FreeTensor on unknown queue '{queue}'"
                    )));
                }
                let data = std::mem::take(&mut self.bufs[idx].data);
                if self.free_bufs.len() < 64 {
                    self.free_bufs.push(data);
                }
            }
            CStmt::GetTBuf { tbuf, var } => {
                let idx = *self
                    .tbuf_idx
                    .get(tbuf)
                    .ok_or_else(|| self.kerr(format!("Get on unknown TBuf '{tbuf}'")))?;
                self.vars.insert(var.clone(), idx);
            }
            CStmt::DataCopy { dst, src, count } | CStmt::DataCopyPad { dst, src, count } => {
                let n = self.eval_usize(count, "DataCopy count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(src, n, ScratchSel::A)?;
                let out = std::mem::take(&mut self.scratch_a);
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::VecBin { op, dst, a, b, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(a, n, ScratchSel::A)?;
                self.read_into(b, n, ScratchSel::B)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                kernels::binary_inplace(&mut out, &self.scratch_b, vec_bin_op(op));
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::VecScalar { op, dst, src, scalar, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                let s = self.eval(scalar)? as f32;
                self.read_into(src, n, ScratchSel::A)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                kernels::scalar_rhs_inplace(&mut out, s, vec_scalar_op(op));
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::VecUn { op, dst, src, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(src, n, ScratchSel::A)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                if let Some(k) = vec_un_op(op) {
                    kernels::unary_inplace(&mut out, k);
                }
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::Duplicate { dst, value, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                let v = self.eval(value)? as f32;
                let mut out = std::mem::take(&mut self.scratch_a);
                out.clear();
                out.resize(n, v);
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::Reduce { kind, dst, src, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(src, n, ScratchSel::A)?;
                if n == 0 {
                    return Err(self.kerr("Reduce over zero elements".into()));
                }
                let result = match kind {
                    ReduceKind::Sum => kernels::fold_f32(&self.scratch_a, 0.0, BinOp::Add),
                    ReduceKind::Max => {
                        kernels::fold_f32(&self.scratch_a, f32::NEG_INFINITY, BinOp::Max)
                    }
                    ReduceKind::Min => {
                        kernels::fold_f32(&self.scratch_a, f32::INFINITY, BinOp::Min)
                    }
                };
                self.write_from(dst, &[result])?;
            }
            CStmt::Scan { kind, dst, src, count, reverse } => {
                let n = self.eval_usize(count, "count")?;
                self.step(n as u64)?;
                self.read_into(src, n, ScratchSel::A)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                let apply = |acc: f32, x: f32| match kind {
                    ScanKind::Sum => acc + x,
                    ScanKind::Prod => acc * x,
                };
                let mut acc = match kind {
                    ScanKind::Sum => 0.0,
                    ScanKind::Prod => 1.0,
                };
                if *reverse {
                    for i in (0..n).rev() {
                        acc = apply(acc, out[i]);
                        out[i] = acc;
                    }
                } else {
                    for x in out.iter_mut() {
                        acc = apply(acc, *x);
                        *x = acc;
                    }
                }
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::SelectGe { dst, cond, a, b, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(cond, n, ScratchSel::A)?;
                std::mem::swap(&mut self.scratch_a, &mut self.scratch_c);
                let cvals = std::mem::take(&mut self.scratch_c);
                self.read_into(a, n, ScratchSel::A)?;
                self.read_into(b, n, ScratchSel::B)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                kernels::select_if_negative(&mut out[..n], &cvals[..n], &self.scratch_b[..n]);
                self.write_from(dst, &out)?;
                self.scratch_a = out;
                self.scratch_c = cvals;
            }
            CStmt::Mmad { c, a, b, m, k, n } => {
                let (m, k, n) = (
                    self.eval_usize(m, "m")?,
                    self.eval_usize(k, "k")?,
                    self.eval_usize(n, "n")?,
                );
                self.step((m * k * n / 64 + 1) as u64)?;
                self.read_into(a, m * k, ScratchSel::A)?;
                std::mem::swap(&mut self.scratch_a, &mut self.scratch_c);
                let avals = std::mem::take(&mut self.scratch_c);
                self.read_into(b, k * n, ScratchSel::B)?;
                self.read_into(c, m * n, ScratchSel::A)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                kernels::matmul_acc(&mut out[..m * n], &avals[..m * k], &self.scratch_b[..k * n], m, k, n);
                self.write_from(c, &out)?;
                self.scratch_a = out;
                self.scratch_c = avals;
            }
            CStmt::SetValue { tensor, index, value } => {
                let idx = self.eval_usize(index, "index")?;
                let v = self.eval(value)? as f32;
                let base = self.eval_usize(&tensor.offset, "offset")?;
                match self.resolve(&tensor.name)? {
                    Resolved::Local(i) => {
                        let buf = &mut self.bufs[i];
                        let pos = base + idx;
                        if pos >= buf.data.len() {
                            return Err(SimError::Oob(format!(
                                "SetValue at {pos} in local '{}' (capacity {})",
                                tensor.name,
                                buf.data.len()
                            )));
                        }
                        buf.data[pos] =
                            if buf.dtype == DType::F16 { f16_round_trip(v) } else { v };
                    }
                    Resolved::Global(_) => {
                        return Err(self.kerr(format!(
                            "SetValue on GlobalTensor '{}' (scalar GM writes unsupported)",
                            tensor.name
                        )));
                    }
                }
            }
            CStmt::GetValue { var, tensor, index } => {
                let idx = self.eval_usize(index, "index")?;
                let base = self.eval_usize(&tensor.offset, "offset")?;
                let v = match self.resolve(&tensor.name)? {
                    Resolved::Local(i) => {
                        let buf = &self.bufs[i];
                        let pos = base + idx;
                        if pos >= buf.data.len() {
                            return Err(SimError::Oob(format!(
                                "GetValue at {pos} in local '{}' (capacity {})",
                                tensor.name,
                                buf.data.len()
                            )));
                        }
                        buf.data[pos]
                    }
                    Resolved::Global(_) => {
                        return Err(self.kerr(format!(
                            "GetValue on GlobalTensor '{}' (stage data must come through queues)",
                            tensor.name
                        )));
                    }
                };
                self.scalars.insert(var.clone(), v as f64);
            }
            CStmt::Cast { dst, src, to, count } => {
                let n = self.eval_usize(count, "count")?;
                self.step((n / 64 + 1) as u64)?;
                self.read_into(src, n, ScratchSel::A)?;
                let mut out = std::mem::take(&mut self.scratch_a);
                match to {
                    DType::F16 => out.iter_mut().for_each(|x| *x = f16_round_trip(*x)),
                    DType::I32 => out.iter_mut().for_each(|x| *x = x.trunc()),
                    DType::I8 => out.iter_mut().for_each(|x| *x = x.trunc().clamp(-128.0, 127.0)),
                    _ => {}
                }
                self.write_from(dst, &out)?;
                self.scratch_a = out;
            }
            CStmt::For { var, start, end, step, body } => {
                let s = self.eval(start)?;
                let e = self.eval(end)?;
                let st = self.eval(step)?;
                if st <= 0.0 {
                    return Err(self.kerr(format!("for-loop step {st} must be positive")));
                }
                let mut i = s;
                while i < e {
                    self.scalars.insert(var.clone(), i);
                    for b in body {
                        self.exec(b)?;
                    }
                    i += st;
                }
            }
            CStmt::While { cond, body } => {
                let mut guard = 0u64;
                while self.eval(cond)? != 0.0 {
                    for b in body {
                        self.exec(b)?;
                    }
                    guard += 1;
                    if guard > 10_000_000 {
                        return Err(SimError::StepLimit);
                    }
                }
            }
            CStmt::If { cond, then, orelse } => {
                let c = self.eval(cond)?;
                let branch = if c != 0.0 { then } else { orelse };
                for s in branch {
                    self.exec(s)?;
                }
            }
            CStmt::CallStage { name, args } => {
                let stage = self
                    .kernel
                    .stage(name)
                    .ok_or_else(|| self.kerr(format!("call to unknown stage '{name}'")))?;
                if stage.params.len() != args.len() {
                    return Err(self.kerr(format!(
                        "stage '{name}' arity mismatch: {} params, {} args",
                        stage.params.len(),
                        args.len()
                    )));
                }
                for (p, a) in stage.params.iter().zip(args) {
                    let v = self.eval(a)?;
                    self.scalars.insert(p.clone(), v);
                }
                for s in &stage.body {
                    self.exec(s)?;
                }
            }
            // cross-core barrier: purely a timing construct
            CStmt::SyncAll => {}
        }
        Ok(())
    }
}
