//! Pluggable execution backends: the compile/simulate surface of the
//! pipeline as a first-class API.
//!
//! The paper's pipeline ends in backend-specific lowering and execution
//! (an Ascend 910B testbed there; the NPU simulator here). This module
//! makes that seam explicit: a [`Backend`] owns the *compile gate*
//! (structural validation of the transpiled program), *execution* of the
//! compiled kernel over concrete host tensors, and the *baseline cost
//! hook* the Fastₓ ratio divides by. The staged pipeline
//! (`crate::coordinator::stage`) is parameterized by `Arc<dyn Backend>`
//! — `CompileStage`/`SimulateStage` never call `ascendc::validate` or
//! `sim::exec` directly — so new targets slot in as alternative
//! compile/simulate implementations without touching the stage driver.
//!
//! Two backends ship built in:
//!
//! * [`AscendSimBackend`] (`"ascend-sim"`, the default) — the NPU
//!   functional + timing simulator. Results are bit-identical to the
//!   pre-registry pipeline.
//! * [`CpuRefBackend`] (`"cpu-ref"`) — executes the transpiled program
//!   functionally on the shared op-kernel layer (`crate::util::kernels`)
//!   with no timing model: fast Pass@1 triage, no Fastₓ cycles.
//!
//! [`BackendRegistry`] provides name-based lookup for the CLI
//! (`suite --backend ascend-sim|cpu-ref|all`, `compile --backend …`) and
//! an embedding point for custom backends.

pub mod ascend_sim;
pub mod cpu_ref;

pub use ascend_sim::AscendSimBackend;
pub use cpu_ref::CpuRefBackend;

use crate::ascendc::validate::{validate, ValidateEnv};
use crate::ascendc::AscProgram;
use crate::baselines::eager::eager_cycles_with_cores;
use crate::bench_suite::spec::TaskSpec;
use crate::coordinator::stage::{Diagnostic, Session};
use crate::util::tensor::Tensor;
use std::collections::HashMap;
use std::sync::Arc;

/// Canonical name of the default (NPU simulator) backend.
pub const BACKEND_ASCEND_SIM: &str = "ascend-sim";
/// Canonical name of the CPU-reference (functional-only) backend.
pub const BACKEND_CPU_REF: &str = "cpu-ref";

/// A backend-compiled kernel: the program that passed the backend's
/// compile gate, plus the concrete tiling it was validated against and
/// the name of the backend that produced it.
#[derive(Clone, Debug)]
pub struct CompiledKernel {
    /// Name of the backend that compiled it (a `BACKEND_*` constant for
    /// the built-ins).
    pub backend: &'static str,
    /// The validated AscendC program.
    pub program: AscProgram,
    /// Concrete tiling values the compile gate validated against.
    pub tiling: HashMap<String, i64>,
}

/// Everything a backend's compile gate produces: the compiled kernel (the
/// kernel is produced even when compilation failed, so artifact dumps can
/// still print the rejected program), every diagnostic in validator order
/// (warnings included), and the first error if any.
#[derive(Clone, Debug)]
pub struct CompileReport {
    pub kernel: CompiledKernel,
    /// All diagnostics, converted to the structured pipeline form.
    pub diagnostics: Vec<Diagnostic>,
    /// First error-severity diagnostic — `Some` means compilation failed.
    pub error: Option<Diagnostic>,
}

impl CompileReport {
    /// Did the program pass the compile gate?
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Result of executing a compiled kernel on a backend.
#[derive(Clone, Debug)]
pub struct ExecOutput {
    /// All host tensors after execution (outputs written in place).
    pub tensors: HashMap<String, Tensor>,
    /// Modeled device cycles, when the backend has a timing model
    /// (`None` for functional-only backends; the task then has no Fastₓ
    /// speedup, matching "incorrect kernels are never fast").
    pub cycles: Option<f64>,
}

/// An execution backend: the compile gate + kernel execution + baseline
/// cost model behind the pipeline's `CompileStage`/`SimulateStage`.
///
/// Implementations must be `Send + Sync`: one backend instance is shared
/// by every worker of a suite run via `Arc<dyn Backend>`.
pub trait Backend: Send + Sync {
    /// Stable backend name (`suite --backend <name>` selects by it).
    fn name(&self) -> &'static str;

    /// The compile gate: validate `program` against the session's
    /// concrete tiling. Takes the program by value (the stage moves it
    /// out of the session) and returns it inside the [`CompiledKernel`].
    fn compile(&self, session: &Session, program: AscProgram) -> CompileReport;

    /// Execute a compiled kernel over owned host tensors with the
    /// configured core count. Functional failures come back as structured
    /// [`Diagnostic`]s (the simulate stage's `S…` code family).
    fn execute(
        &self,
        kernel: &CompiledKernel,
        inputs: HashMap<String, Tensor>,
        cores: usize,
    ) -> Result<ExecOutput, Diagnostic>;

    /// Baseline cost of the task's eager reference decomposition, in the
    /// backend's cycle units — the denominator of the Fastₓ ratio. The
    /// default is the shared PyTorch-eager-on-NPU cost model, so
    /// cross-backend Fastₓ numbers compare like with like.
    fn eager_cycles(&self, task: &TaskSpec, cores: usize) -> f64 {
        eager_cycles_with_cores(task, cores)
    }
}

/// The default backend (what `PipelineConfig::default()` uses):
/// [`AscendSimBackend`].
pub fn default_backend() -> Arc<dyn Backend> {
    Arc::new(AscendSimBackend)
}

/// Shared compile-gate implementation for backends that target the
/// AscendC structural validator (both built-ins do — they differ in
/// *execution*, not in what "compiles"). Reuses the transpile stage's
/// validation result when the session already carries one for this exact
/// program + tiling, so the happy path pays for validation once.
pub fn compile_with_validator(
    backend: &'static str,
    session: &Session,
    program: AscProgram,
) -> CompileReport {
    let raw = if session.transpiled {
        session.compile_diags.clone()
    } else {
        validate(&program, &ValidateEnv::new(session.tiling.clone()))
    };
    let mut diagnostics = Vec::with_capacity(raw.len());
    let mut error = None;
    for d in raw {
        let is_error = d.is_error();
        let converted = Diagnostic::from(d);
        if is_error && error.is_none() {
            error = Some(converted.clone());
        }
        diagnostics.push(converted);
    }
    CompileReport {
        kernel: CompiledKernel { backend, program, tiling: session.tiling.clone() },
        diagnostics,
        error,
    }
}

/// Name-based backend lookup. The `Default` instance (same as
/// [`BackendRegistry::builtin`]) holds the two built-in backends;
/// [`BackendRegistry::register`] adds (or replaces, by name) custom ones.
#[derive(Clone)]
pub struct BackendRegistry {
    entries: Vec<Arc<dyn Backend>>,
}

impl BackendRegistry {
    /// Registry with the built-in backends, in presentation order
    /// (`ascend-sim` first — it is the default).
    pub fn builtin() -> BackendRegistry {
        let mut reg = BackendRegistry::empty();
        reg.register(Arc::new(AscendSimBackend));
        reg.register(Arc::new(CpuRefBackend));
        reg
    }

    /// An empty registry (for embedders that only want custom backends).
    pub fn empty() -> BackendRegistry {
        BackendRegistry { entries: Vec::new() }
    }

    /// Register a backend; an existing entry with the same name is
    /// replaced (latest registration wins), preserving its position.
    pub fn register(&mut self, backend: Arc<dyn Backend>) {
        match self.entries.iter().position(|b| b.name() == backend.name()) {
            Some(i) => self.entries[i] = backend,
            None => self.entries.push(backend),
        }
    }

    /// Look up a backend by name.
    pub fn get(&self, name: &str) -> Option<Arc<dyn Backend>> {
        self.entries.iter().find(|b| b.name() == name).cloned()
    }

    /// Registered backend names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|b| b.name()).collect()
    }

    /// All registered backends, in registration order.
    pub fn all(&self) -> Vec<Arc<dyn Backend>> {
        self.entries.clone()
    }
}

impl Default for BackendRegistry {
    fn default() -> BackendRegistry {
        BackendRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_lists_both_backends_in_order() {
        let reg = BackendRegistry::builtin();
        assert_eq!(reg.names(), [BACKEND_ASCEND_SIM, BACKEND_CPU_REF]);
        assert!(reg.get("ascend-sim").is_some());
        assert!(reg.get("cpu-ref").is_some());
        assert!(reg.get("tpu").is_none());
        assert_eq!(reg.all().len(), 2);
    }

    #[test]
    fn register_replaces_by_name_in_place() {
        struct Fake;
        impl Backend for Fake {
            fn name(&self) -> &'static str {
                BACKEND_CPU_REF
            }
            fn compile(&self, session: &Session, program: AscProgram) -> CompileReport {
                compile_with_validator(self.name(), session, program)
            }
            fn execute(
                &self,
                _kernel: &CompiledKernel,
                inputs: HashMap<String, Tensor>,
                _cores: usize,
            ) -> Result<ExecOutput, Diagnostic> {
                Ok(ExecOutput { tensors: inputs, cycles: Some(1.0) })
            }
        }
        let mut reg = BackendRegistry::builtin();
        reg.register(Arc::new(Fake));
        // still two entries, same order, latest registration won
        assert_eq!(reg.names(), [BACKEND_ASCEND_SIM, BACKEND_CPU_REF]);
        let fake = reg.get(BACKEND_CPU_REF).unwrap();
        let kernel = CompiledKernel {
            backend: BACKEND_CPU_REF,
            program: AscProgram {
                host: crate::ascendc::ir::AscHost {
                    name: "h".into(),
                    params: vec![],
                    tiling_assigns: vec![],
                    launches: vec![],
                },
                kernels: vec![],
            },
            tiling: HashMap::new(),
        };
        let out = fake.execute(&kernel, HashMap::new(), 1).unwrap();
        assert_eq!(out.cycles, Some(1.0));
    }

    #[test]
    fn default_backend_is_ascend_sim() {
        assert_eq!(default_backend().name(), BACKEND_ASCEND_SIM);
    }
}
