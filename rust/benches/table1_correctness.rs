//! Bench E1 — regenerates paper Table 1 (Comp@1 / Pass@1 per category)
//! and compares every cell against the published values.
//!
//! criterion is not in the offline crate set; this is a `harness = false`
//! bench binary using std::time. Run: `cargo bench --bench table1_correctness`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use std::time::Instant;

/// Paper Table 1 (Comp@1, Pass@1) per category, in category order.
const PAPER_TABLE1: &[(&str, f64, f64)] = &[
    ("Activation", 100.0, 100.0),
    ("Loss", 100.0, 85.7),
    ("Math", 83.3, 83.3),
    ("Normalization", 100.0, 87.5),
    ("Optimizer", 100.0, 100.0),
    ("Reduce", 100.0, 100.0),
    ("Pooling", 100.0, 66.7),
];
const PAPER_TOTAL: (f64, f64) = (98.1, 90.4);

fn main() {
    let tasks = all_tasks();
    let started = Instant::now();
    let suite = run_suite(&tasks, &SuiteConfig::default());
    let elapsed = started.elapsed().as_secs_f64();

    println!("{}", suite.render_table1());
    println!("pipeline wall-clock for 52 tasks: {elapsed:.1}s\n");

    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>14}",
        "category", "paper Comp@1", "ours Comp@1", "paper Pass@1", "ours Pass@1"
    );
    let rows = suite.by_category();
    let mut all_match = true;
    for ((paper_name, p_comp, p_pass), row) in PAPER_TABLE1.iter().zip(&rows) {
        assert!(row.category.starts_with(paper_name), "category order");
        let (comp, pass) = (row.metrics.comp_pct(), row.metrics.pass_pct());
        let ok = (comp - p_comp).abs() < 0.1 && (pass - p_pass).abs() < 0.1;
        all_match &= ok;
        println!(
            "{:<16} {:>14.1} {:>14.1} {:>14.1} {:>14.1} {}",
            paper_name,
            p_comp,
            comp,
            p_pass,
            pass,
            if ok { "" } else { "  <-- differs" }
        );
    }
    let t = suite.totals();
    println!(
        "{:<16} {:>14.1} {:>14.1} {:>14.1} {:>14.1}",
        "Total",
        PAPER_TOTAL.0,
        t.comp_pct(),
        PAPER_TOTAL.1,
        t.pass_pct()
    );
    assert!((t.comp_pct() - PAPER_TOTAL.0).abs() < 0.1, "total Comp@1");
    assert!((t.pass_pct() - PAPER_TOTAL.1).abs() < 0.1, "total Pass@1");
    println!(
        "\nTable 1: {}",
        if all_match {
            "every category cell matches the paper"
        } else {
            "totals match the paper; per-cell diffs marked above"
        }
    );
}
