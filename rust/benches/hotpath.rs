//! Bench E7 — hot-path microbenchmarks for the §Perf optimization pass:
//!
//! * simulator elementwise throughput (modeled elements / wall second),
//! * full pipeline latency per task class (generation -> verified kernel),
//! * suite wall-clock scaling with worker threads,
//! * DSL frontend + transcompiler throughput.
//!
//! Run: `cargo bench --bench hotpath`

use ascendcraft::bench_suite::tasks::task_by_name;
use ascendcraft::coordinator::pipeline::{run_task, PipelineConfig};
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::dsl;
use ascendcraft::synth::{templates::KnowledgeBaseSynthesizer, Generator};
use ascendcraft::transpile::{transpile, TranspileOptions};
use std::time::Instant;

fn time<T>(label: &str, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    // warmup
    let _ = f();
    let started = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let secs = started.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<46} {:>10.2} ms/iter", secs * 1e3);
    secs
}

fn main() {
    println!("hot-path microbenchmarks (release, single thread unless noted):\n");

    // 1. simulator throughput on a bandwidth-bound elementwise kernel
    let relu = task_by_name("relu").unwrap();
    let n = relu.primary_numel() as f64;
    let secs = time("sim: relu 4.2M elements end-to-end", 5, || {
        run_task(&relu, &PipelineConfig::default())
    });
    println!(
        "{:<46} {:>10.1} M modeled elements/s\n",
        "  -> simulator functional throughput",
        n / secs / 1e6
    );

    // 2. pipeline latency per task class
    for name in ["gelu", "softmax", "adam", "cumsum", "maxpool2d"] {
        let task = task_by_name(name).unwrap();
        time(&format!("pipeline: {name}"), 3, || run_task(&task, &PipelineConfig::default()));
    }
    println!();

    // 3. frontend + transcompiler throughput (no simulation)
    let synth = KnowledgeBaseSynthesizer::default();
    let task = task_by_name("adam").unwrap();
    let gen = synth.generate(&task).unwrap();
    let inputs = {
        let mut m = task.make_inputs(1);
        for (name, shape) in &gen.scratch {
            m.insert(name.clone(), ascendcraft::util::tensor::Tensor::zeros(shape));
        }
        m
    };
    time("dsl: parse+validate adam program", 200, || dsl::frontend(&gen.dsl_source).unwrap());
    let program = dsl::frontend(&gen.dsl_source).unwrap();
    time("transpile: 4 passes adam program", 200, || {
        transpile(&program, &inputs, &TranspileOptions::default()).unwrap()
    });
    println!();

    // 4. worker scaling on a 12-task slice (NOTE: on a single-core host
    // this demonstrates oversubscription cost, not speedup)
    println!(
        "host parallelism: {} core(s)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let slice: Vec<_> = [
        "relu", "gelu", "sigmoid", "silu", "mish", "softsign", "softmax", "rmsnorm", "l2norm",
        "cumsum", "sum_dim", "mse_loss",
    ]
    .iter()
    .map(|n| task_by_name(n).unwrap())
    .collect();
    let mut base = 0.0;
    let max_workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    for workers in [1usize, 2, 4, 8].into_iter().filter(|w| *w <= max_workers.max(2)) {
        let cfg = SuiteConfig {
            workers,
            verbose: false,
            ..Default::default()
        };
        let started = Instant::now();
        let suite = run_suite(&slice, &cfg);
        let secs = started.elapsed().as_secs_f64();
        assert!(suite.totals().correct == slice.len());
        if workers == 1 {
            base = secs;
        }
        println!(
            "suite slice (12 tasks) with {workers} workers: {secs:>6.2}s  (speedup {:.2}x)",
            base / secs
        );
    }
}
