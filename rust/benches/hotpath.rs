//! Bench E7 — hot-path microbenchmarks for the §Perf optimization pass:
//!
//! * simulator elementwise throughput (modeled elements / wall second),
//! * full pipeline latency per task class (generation -> verified kernel),
//! * suite wall-clock scaling with worker threads,
//! * DSL frontend + transcompiler throughput.
//!
//! Run: `cargo bench --bench hotpath`

use ascendcraft::analysis::{analyze, AnalyzeEnv, Cfg};
use ascendcraft::backend::{Backend as _, BackendRegistry};
use ascendcraft::bench_suite::tasks::task_by_name;
use ascendcraft::coordinator::pipeline::{run_task, PipelineConfig};
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::dsl;
use ascendcraft::runtime::hlo::{evaluate, parse_module, ExecutablePlan, PlanOptions, PlanScratch};
use ascendcraft::runtime::GoldenOracle;
use ascendcraft::synth::{templates::KnowledgeBaseSynthesizer, Generator};
use ascendcraft::transpile::{transpile, TranspileOptions};
use ascendcraft::util::tensor::Tensor;
use std::time::Instant;

fn time<T>(label: &str, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    // warmup
    let _ = f();
    let started = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let secs = started.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<46} {:>10.2} ms/iter", secs * 1e3);
    secs
}

fn main() {
    println!("hot-path microbenchmarks (release, single thread unless noted):\n");

    // 0. oracle group: the compile-once/execute-many HLO plan vs the
    // retired tree-walking evaluator, on checked-in fixtures. The
    // acceptance bar for the plan refactor is >= 2x end-to-end.
    println!("oracle (golden HLO execution, checked-in fixtures):");
    for name in ["relu", "softmax", "mse_loss"] {
        let path = format!("{}/../artifacts/{name}.hlo.txt", env!("CARGO_MANIFEST_DIR"));
        let text = std::fs::read_to_string(&path).expect("checked-in fixture");
        let module = parse_module(&text).unwrap();
        let task = task_by_name(name).unwrap();
        let inputs = task.make_inputs(7);
        let ins: Vec<&Tensor> = task.inputs.iter().map(|(n, _, _)| &inputs[*n]).collect();

        time(&format!("oracle[{name}]: plan compile"), 50, || {
            ExecutablePlan::compile(&module).unwrap()
        });
        let plan = ExecutablePlan::compile(&module).unwrap();
        let plan_noarena =
            ExecutablePlan::compile_with(&module, PlanOptions { reuse_buffers: false }).unwrap();

        // sanity: identical numerics before timing anything
        let want = evaluate(&module, &ins).unwrap();
        let got = plan.execute(&ins).unwrap();
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert!(
                ascendcraft::util::compare::allclose(g, w, 0.0, 0.0),
                "{name}: plan diverged from evaluator"
            );
        }

        let t_eval = time(&format!("oracle[{name}]: tree-walk evaluate"), 5, || {
            evaluate(&module, &ins).unwrap()
        });
        let t_noarena = time(&format!("oracle[{name}]: plan execute (arena off)"), 5, || {
            plan_noarena.execute(&ins).unwrap()
        });
        let mut scratch = PlanScratch::default();
        let t_plan = time(&format!("oracle[{name}]: plan execute (arena on)"), 5, || {
            plan.execute_with_scratch(&ins, &mut scratch).unwrap()
        });
        println!(
            "{:<46} {:>9.2}x (arena) / {:.2}x (no arena)",
            "  -> plan speedup vs tree-walker",
            t_eval / t_plan,
            t_eval / t_noarena
        );

        // batched multi-seed execution (the suite --golden-seeds path):
        // N seeds through one run_batch, sharing a single PlanScratch,
        // vs N independent run() calls each paying fresh-arena setup
        const SEEDS: usize = 8;
        let oracle = GoldenOracle::from_text(name, &text).unwrap();
        let seed_inputs: Vec<_> = (0..SEEDS as u64).map(|s| task.make_inputs(7 + s)).collect();
        let batches: Vec<Vec<&Tensor>> = seed_inputs
            .iter()
            .map(|m| task.inputs.iter().map(|(n, _, _)| &m[*n]).collect())
            .collect();
        // sanity: batch results == per-seed results, bitwise
        let batched = oracle.run_batch(&batches).unwrap();
        for (b, ins) in batched.iter().zip(&batches) {
            let single = oracle.run(ins).unwrap();
            assert_eq!(b.len(), single.len());
            for (x, y) in b.iter().zip(&single) {
                assert_eq!(x.data, y.data, "{name}: run_batch diverged from run");
            }
        }
        let t_single = time(&format!("oracle[{name}]: {SEEDS} seeds via run()"), 5, || {
            batches.iter().map(|ins| oracle.run(ins).unwrap()).collect::<Vec<_>>()
        });
        let mut bscratch = PlanScratch::default();
        let t_batch = time(&format!("oracle[{name}]: {SEEDS} seeds via run_batch"), 5, || {
            oracle.run_batch_with_scratch(&batches, &mut bscratch).unwrap()
        });
        println!(
            "{:<46} {:>9.2}x\n",
            "  -> run_batch speedup vs per-seed run",
            t_single / t_batch
        );
    }

    // 1. simulator throughput on a bandwidth-bound elementwise kernel
    let relu = task_by_name("relu").unwrap();
    let n = relu.primary_numel() as f64;
    let secs = time("sim: relu 4.2M elements end-to-end", 5, || {
        run_task(&relu, &PipelineConfig::default())
    });
    println!(
        "{:<46} {:>10.1} M modeled elements/s\n",
        "  -> simulator functional throughput",
        n / secs / 1e6
    );

    // 2. pipeline latency per task class
    for name in ["gelu", "softmax", "adam", "cumsum", "maxpool2d"] {
        let task = task_by_name(name).unwrap();
        time(&format!("pipeline: {name}"), 3, || run_task(&task, &PipelineConfig::default()));
    }
    println!();

    // 2b. pipeline group: per-stage wall time from the session's
    // StageReports, one representative task per category — the tracked
    // baseline for the staged compilation-session API's timings
    println!("pipeline stage timings (mean of {PIPELINE_ITERS} runs, ms):");
    println!(
        "{:<28} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "task", "generate", "frontend", "transpile", "analyze", "compile", "simulate", "score"
    );
    const PIPELINE_ITERS: usize = 3;
    for name in ["gelu", "mse_loss", "cumsum", "rmsnorm", "adam", "sum_dim", "maxpool2d"] {
        let task = task_by_name(name).unwrap();
        let cfg = PipelineConfig::default();
        let _ = run_task(&task, &cfg); // warmup
        // the stage list is deterministic per config, so reports line up
        // run-to-run; accumulate by position
        let mut names: Vec<&'static str> = Vec::new();
        let mut acc: Vec<f64> = Vec::new();
        for _ in 0..PIPELINE_ITERS {
            let art = run_task(&task, &cfg);
            if names.is_empty() {
                names = art.result.stage_timings.iter().map(|r| r.name).collect();
                acc = vec![0.0; names.len()];
            }
            for (slot, report) in acc.iter_mut().zip(&art.result.stage_timings) {
                *slot += report.wall_secs;
            }
        }
        let mut row = format!("{:<28}", format!("pipeline[{name}]"));
        let stages =
            ["generate", "frontend", "transpile", "analyze", "compile", "simulate", "score"];
        for stage in stages {
            match names.iter().position(|n| *n == stage) {
                Some(i) => {
                    row.push_str(&format!(" {:>9.3}", acc[i] / PIPELINE_ITERS as f64 * 1e3))
                }
                None => row.push_str(&format!(" {:>9}", "-")),
            }
        }
        println!("{row}");
    }
    println!();

    // 2c. backend group: per-task execute time of the SAME compiled
    // kernel on every registered backend — the timing simulator
    // (ascend-sim) vs the functional-only triage path (cpu-ref)
    println!("backend execute (compiled kernel reused, fresh inputs per iter):");
    let registry = BackendRegistry::builtin();
    for name in ["relu", "softmax", "adam"] {
        let task = task_by_name(name).unwrap();
        let cfg = PipelineConfig::default();
        let art = run_task(&task, &cfg);
        assert!(art.result.correct, "{name}: {:?}", art.result.failure);
        let kernel = art.session.kernel.clone().expect("compile stage produced a kernel");
        // rebuild the simulate-stage inputs: task tensors + generator scratch
        let synth = KnowledgeBaseSynthesizer::default();
        let gen = synth.generate(&task).unwrap();
        let mut inputs = task.make_inputs(cfg.seed);
        for (sname, shape) in &gen.scratch {
            inputs.insert(sname.clone(), ascendcraft::util::tensor::Tensor::zeros(shape));
        }
        let mut secs = Vec::new();
        for backend in registry.all() {
            let s = time(&format!("backend[{name}]: execute on {}", backend.name()), 5, || {
                backend.execute(&kernel, inputs.clone(), cfg.cores).expect("execute succeeds")
            });
            secs.push(s);
        }
        if let [sim_secs, cpu_secs] = secs[..] {
            println!(
                "{:<46} {:>9.2}x",
                "  -> cpu-ref speedup vs ascend-sim",
                sim_secs / cpu_secs
            );
        }
    }
    println!();

    // 3. frontend + transcompiler throughput (no simulation)
    let synth = KnowledgeBaseSynthesizer::default();
    let task = task_by_name("adam").unwrap();
    let gen = synth.generate(&task).unwrap();
    let inputs = {
        let mut m = task.make_inputs(1);
        for (name, shape) in &gen.scratch {
            m.insert(name.clone(), ascendcraft::util::tensor::Tensor::zeros(shape));
        }
        m
    };
    time("dsl: parse+validate adam program", 200, || dsl::frontend(&gen.dsl_source).unwrap());
    let program = dsl::frontend(&gen.dsl_source).unwrap();
    time("transpile: 4 passes adam program", 200, || {
        transpile(&program, &inputs, &TranspileOptions::default()).unwrap()
    });
    println!();

    // 3b. analysis group: the CFG/dataflow lint passes over the
    // transpiled IR (the analyze stage's whole cost, then the CFG
    // construction alone)
    let out = transpile(&program, &inputs, &TranspileOptions::default()).unwrap();
    let numel: std::collections::HashMap<String, usize> =
        inputs.iter().map(|(n, t)| (n.clone(), t.numel())).collect();
    let aenv = AnalyzeEnv::new(out.tiling.clone()).with_numel(numel);
    time("analysis: all passes, adam program", 200, || analyze(&out.program, &aenv));
    let first_kernel = &out.program.kernels[0];
    time("analysis: CFG build, adam kernel", 500, || Cfg::build(first_kernel));
    println!();

    // 4. worker scaling on a 12-task slice (NOTE: on a single-core host
    // this demonstrates oversubscription cost, not speedup)
    println!(
        "host parallelism: {} core(s)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let slice: Vec<_> = [
        "relu", "gelu", "sigmoid", "silu", "mish", "softsign", "softmax", "rmsnorm", "l2norm",
        "cumsum", "sum_dim", "mse_loss",
    ]
    .iter()
    .map(|n| task_by_name(n).unwrap())
    .collect();
    let mut base = 0.0;
    let max_workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    for workers in [1usize, 2, 4, 8].into_iter().filter(|w| *w <= max_workers.max(2)) {
        let cfg = SuiteConfig {
            workers,
            verbose: false,
            ..Default::default()
        };
        let started = Instant::now();
        let suite = run_suite(&slice, &cfg);
        let secs = started.elapsed().as_secs_f64();
        assert!(suite.totals().correct == slice.len());
        if workers == 1 {
            base = secs;
        }
        println!(
            "suite slice (12 tasks) with {workers} workers: {secs:>6.2}s  (speedup {:.2}x)",
            base / secs
        );
    }
}
