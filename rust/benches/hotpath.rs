//! Bench E7 — hot-path microbenchmarks for the §Perf optimization pass:
//!
//! * op-kernel layer: tiled/packed `matmul_acc` vs the naive reference,
//!   and elementwise/reduction thread scaling on the worker pool
//!   (bit-identical at every width — asserted before timing),
//! * oracle: the compile-once/execute-many HLO plan vs the tree-walking
//!   evaluator, plus wave-parallel plan execution,
//! * full pipeline latency per task class (generation -> verified kernel),
//! * suite wall-clock scaling with worker threads,
//! * DSL frontend + transcompiler throughput.
//!
//! Run: `cargo bench --bench hotpath`
//!
//! Args (after `--`): `--quick` runs only the kernel groups at reduced
//! sizes (the CI snapshot mode); `--json PATH` writes the kernel-group
//! medians as a machine-readable snapshot (see `BENCH_PR10.json` at the
//! repo root for the checked-in trajectory baseline) and exits non-zero
//! if the snapshot fails its own validation.

use ascendcraft::analysis::{analyze, AnalyzeEnv, Cfg};
use ascendcraft::backend::{Backend as _, BackendRegistry};
use ascendcraft::bench_suite::tasks::task_by_name;
use ascendcraft::coordinator::pipeline::{run_task, PipelineConfig};
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::dsl;
use ascendcraft::runtime::hlo::{evaluate, parse_module, ExecutablePlan, PlanOptions, PlanScratch};
use ascendcraft::runtime::GoldenOracle;
use ascendcraft::serve::{Daemon, KernelRequest, ServeConfig};
use ascendcraft::synth::{templates::KnowledgeBaseSynthesizer, Generator};
use ascendcraft::transpile::{transpile, TranspileOptions};
use ascendcraft::tune::{tune_task, TuneOptions};
use ascendcraft::util::json::Json;
use ascendcraft::util::kernels::{self, UnaryOp};
use ascendcraft::util::pool::WorkerPool;
use ascendcraft::util::rng::XorShiftRng;
use ascendcraft::util::tensor::Tensor;
use std::time::Instant;

fn time<T>(label: &str, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    // warmup
    let _ = f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let started = Instant::now();
        std::hint::black_box(f());
        samples.push(started.elapsed().as_secs_f64());
    }
    samples.sort_by(f64::total_cmp);
    let secs = samples[samples.len() / 2]; // median
    println!("{label:<46} {:>10.2} ms/iter", secs * 1e3);
    secs
}

/// Kernel-group medians collected for the machine-readable snapshot
/// (`--json PATH`): `group -> metric -> value`. Serialization goes
/// through [`Json`]'s BTreeMap objects, so file keys come out sorted
/// and snapshot diffs stay stable across runs.
#[derive(Default)]
struct Snapshot {
    groups: Vec<(String, Vec<(String, f64)>)>,
}

/// Groups the snapshot must contain — the CI quick-mode step fails when
/// one is missing or the JSON does not reparse.
const REQUIRED_GROUPS: [&str; 5] = ["matmul", "elementwise", "reduction", "serve", "tune"];

impl Snapshot {
    fn metric(&mut self, group: &str, name: &str, value: f64) {
        match self.groups.iter_mut().find(|(g, _)| g == group) {
            Some((_, metrics)) => metrics.push((name.to_string(), value)),
            None => self.groups.push((group.to_string(), vec![(name.to_string(), value)])),
        }
    }

    fn to_json(&self, quick: bool) -> Json {
        let mut groups = Json::obj();
        for (group, metrics) in &self.groups {
            let mut g = Json::obj();
            for (name, value) in metrics {
                g.set(name, *value);
            }
            groups.set(group, g);
        }
        let mut j = Json::obj();
        j.set("bench", "hotpath")
            .set("version", 1usize)
            .set("mode", if quick { "quick" } else { "full" })
            .set("note", "ms metrics are medians; speedups are vs the serial baseline");
        j.set("groups", groups);
        j
    }
}

fn write_snapshot(path: &str, snap: &Snapshot, quick: bool) -> Result<(), String> {
    let text = snap.to_json(quick).to_pretty();
    // self-validation before anything touches disk: the snapshot must
    // reparse through the same hand-rolled JSON layer and contain every
    // required group (a malformed trajectory file is worse than none)
    let parsed = Json::parse(&text).map_err(|e| format!("snapshot does not reparse: {e}"))?;
    let groups = match parsed.get("groups") {
        Some(g) => g,
        None => return Err("snapshot missing 'groups'".to_string()),
    };
    for g in REQUIRED_GROUPS {
        if groups.get(g).is_none() {
            return Err(format!("snapshot missing required group '{g}'"));
        }
    }
    std::fs::write(path, text + "\n").map_err(|e| format!("write {path}: {e}"))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    println!(
        "hot-path microbenchmarks (release, {}):\n",
        if quick {
            "quick mode: kernel groups only"
        } else {
            "single thread unless noted"
        }
    );

    let mut snap = Snapshot::default();
    let kiters = if quick { 3 } else { 5 };

    // K1. matmul: the naive triple loop (the accumulation-order contract
    // reference) vs the tiled/packed kernel, single thread. The largest
    // shape is the acceptance gate for the tiling work.
    println!("kernel: matmul_acc naive vs tiled/packed (1 thread):");
    let serial = WorkerPool::new(1);
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(96, 96, 96), (192, 192, 192)]
    } else {
        &[(128, 128, 128), (256, 256, 256), (384, 384, 384), (512, 512, 512)]
    };
    let mut rng = XorShiftRng::new(0xBE7C);
    let mut matmul_speedup = 0.0;
    for &(m, k, n) in shapes {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        // bitwise identity before timing anything
        let mut c_naive = vec![0.0f32; m * n];
        kernels::matmul_acc_naive(&mut c_naive, &a, &b, m, k, n);
        let mut c_tiled = vec![0.0f32; m * n];
        serial.install(|| kernels::matmul_acc(&mut c_tiled, &a, &b, m, k, n));
        assert!(
            c_naive.iter().zip(&c_tiled).all(|(x, y)| x.to_bits() == y.to_bits()),
            "matmul {m}x{k}x{n}: tiled kernel diverged from naive"
        );
        let label = format!("{m}x{k}x{n}");
        let mut c = vec![0.0f32; m * n];
        let t_naive = time(&format!("matmul[{label}]: naive"), kiters, || {
            kernels::fill(&mut c, 0.0);
            kernels::matmul_acc_naive(&mut c, &a, &b, m, k, n);
        });
        let t_tiled = serial.install(|| {
            time(&format!("matmul[{label}]: tiled"), kiters, || {
                kernels::fill(&mut c, 0.0);
                kernels::matmul_acc(&mut c, &a, &b, m, k, n);
            })
        });
        matmul_speedup = t_naive / t_tiled;
        println!("{:<46} {matmul_speedup:>9.2}x", "  -> tiled speedup vs naive");
        snap.metric("matmul", &format!("{label} naive ms"), t_naive * 1e3);
        snap.metric("matmul", &format!("{label} tiled ms"), t_tiled * 1e3);
        snap.metric("matmul", &format!("{label} speedup"), matmul_speedup);
    }
    snap.metric("matmul", "largest shape speedup", matmul_speedup);
    println!();

    // K2. elementwise + row-reduction thread scaling on the worker pool.
    // logistic is self-stabilizing (outputs in (0,1)), so re-running it
    // in place does identical work every iteration; the reduction reads
    // an immutable source. Partitions are deterministic and bit-identical
    // at every width (see tests/determinism.rs).
    println!("kernel: elementwise / reduction thread scaling:");
    let n_elem = if quick { 1 << 21 } else { 1 << 24 };
    let mut buf = rng.normal_vec(n_elem);
    let mut base = 0.0;
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let secs = pool.install(|| {
            time(&format!("elementwise[logistic {n_elem}]: {threads} thr"), kiters, || {
                kernels::unary_inplace(&mut buf, UnaryOp::Logistic)
            })
        });
        if threads == 1 {
            base = secs;
        }
        snap.metric("elementwise", &format!("logistic {threads}t ms"), secs * 1e3);
        snap.metric("elementwise", &format!("logistic {threads}t speedup"), base / secs);
    }
    let cols = 1024usize;
    let rows = n_elem / cols;
    let src = std::mem::take(&mut buf);
    let mut out = vec![0.0f32; rows];
    let mut base_r = 0.0;
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let secs = pool.install(|| {
            time(&format!("reduction[row-sum {rows}x{cols}]: {threads} thr"), kiters, || {
                kernels::reduce_rows_wide(&src, cols, 0.0, false, &mut out)
            })
        });
        if threads == 1 {
            base_r = secs;
        }
        snap.metric("reduction", &format!("row-sum {threads}t ms"), secs * 1e3);
        snap.metric("reduction", &format!("row-sum {threads}t speedup"), base_r / secs);
    }
    println!();

    // K3. serve loadgen: a mixed request stream (including the failing
    // mask_cumsum — failures are cached too) replayed against an
    // in-process daemon, cold then warm. The cold pass runs every task
    // through the full pipeline; the warm pass must be all cache hits
    // with no stages run. The warm/cold ratio is the cache's value and
    // is host-independent (the `--compare` gate checks only ratios).
    // Measured with raw Instant, not `time()` — the cold pass is not
    // idempotent (a warmup would fill the cache and erase it).
    println!("serve: mixed request stream, cold vs warm cache:");
    let serve_tasks: &[&str] = if quick {
        &["relu", "gelu", "mse_loss", "mask_cumsum"]
    } else {
        &["relu", "gelu", "softmax", "adam", "cumsum", "mse_loss", "mask_cumsum", "l2norm"]
    };
    let daemon =
        Daemon::start(ServeConfig { workers: 2, ..ServeConfig::default() }).expect("start daemon");
    let mut cold_secs = 0.0;
    for phase in ["cold", "warm"] {
        let started = Instant::now();
        let tickets: Vec<_> = serve_tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mut req = KernelRequest::new(t);
                req.id = i as u64;
                daemon.submit(req)
            })
            .collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        let secs = started.elapsed().as_secs_f64();
        for r in &responses {
            assert!(r.ok, "serve bench: request rejected: {:?}", r.error);
            assert!(r.result.is_some(), "serve bench: served request must carry a result");
            if phase == "warm" {
                assert!(r.cache_hit || r.coalesced, "warm pass must be served from cache");
            }
        }
        println!(
            "{:<46} {:>10.2} ms",
            format!("serve[{} tasks]: {phase} pass", serve_tasks.len()),
            secs * 1e3
        );
        snap.metric("serve", &format!("{phase} ms"), secs * 1e3);
        if phase == "cold" {
            cold_secs = secs;
        } else {
            let speedup = cold_secs / secs;
            println!("{:<46} {speedup:>9.2}x", "  -> warm speedup vs cold");
            snap.metric("serve", "warm speedup", speedup);
        }
    }
    let stats = daemon.stats();
    let hit_rate = stats.hit_rate().expect("generate requests completed");
    println!("{:<46} {:>9.1}%", "  -> cache hit rate across both passes", hit_rate * 100.0);
    snap.metric("serve", "warm hit rate", hit_rate);
    drop(daemon);
    println!();

    // K4. tune: the autotuner's search loop on a representative
    // elementwise task — wall time of one full tune_task() search plus
    // the tuned-vs-untuned simulated-cycle ratio. The ratio is exact
    // and host-independent (the search is deterministic), so it is the
    // metric the `--compare` gate tracks; the wall-ms median tracks
    // search-loop overhead per evaluation.
    println!("tune: cost-model-guided search (relu):");
    let tune_spec = task_by_name("relu").unwrap();
    let tune_base = PipelineConfig::default();
    let tune_opts = TuneOptions { budget: if quick { 8 } else { 16 }, beam: 2 };
    let t_tune = time("tune[relu]: full search", if quick { 2 } else { 3 }, || {
        tune_task(&tune_spec, &tune_base, &tune_opts)
    });
    let outcome = tune_task(&tune_spec, &tune_base, &tune_opts);
    let tune_baseline = outcome.baseline_cycles.expect("relu baseline simulates");
    let tune_best = outcome.best.as_ref().map(|(_, c)| *c).unwrap_or(tune_baseline);
    let tune_ratio = tune_baseline / tune_best;
    println!(
        "{:<46} {tune_ratio:>9.2}x ({} evals)",
        "  -> tuned speedup vs untuned (sim cycles)", outcome.evals
    );
    snap.metric("tune", "search ms", t_tune * 1e3);
    snap.metric("tune", "evals", outcome.evals as f64);
    snap.metric("tune", "cycle speedup", tune_ratio);
    println!();

    if let Some(path) = &json_path {
        match write_snapshot(path, &snap, quick) {
            Ok(()) => println!("snapshot written to {path}\n"),
            Err(e) => {
                eprintln!("snapshot error: {e}");
                std::process::exit(1);
            }
        }
    }
    if quick {
        return;
    }

    // 0. oracle group: the compile-once/execute-many HLO plan vs the
    // retired tree-walking evaluator, on checked-in fixtures. The
    // acceptance bar for the plan refactor is >= 2x end-to-end.
    println!("oracle (golden HLO execution, checked-in fixtures):");
    for name in ["relu", "softmax", "mse_loss"] {
        let path = format!("{}/../artifacts/{name}.hlo.txt", env!("CARGO_MANIFEST_DIR"));
        let text = std::fs::read_to_string(&path).expect("checked-in fixture");
        let module = parse_module(&text).unwrap();
        let task = task_by_name(name).unwrap();
        let inputs = task.make_inputs(7);
        let ins: Vec<&Tensor> = task.inputs.iter().map(|(n, _, _)| &inputs[*n]).collect();

        time(&format!("oracle[{name}]: plan compile"), 50, || {
            ExecutablePlan::compile(&module).unwrap()
        });
        let plan = ExecutablePlan::compile(&module).unwrap();
        let plan_noarena = ExecutablePlan::compile_with(
            &module,
            PlanOptions { reuse_buffers: false, parallel: false },
        )
        .unwrap();

        // sanity: identical numerics before timing anything
        let want = evaluate(&module, &ins).unwrap();
        let got = plan.execute(&ins).unwrap();
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert!(
                ascendcraft::util::compare::allclose(g, w, 0.0, 0.0),
                "{name}: plan diverged from evaluator"
            );
        }

        let t_eval = time(&format!("oracle[{name}]: tree-walk evaluate"), 5, || {
            evaluate(&module, &ins).unwrap()
        });
        let t_noarena = time(&format!("oracle[{name}]: plan execute (arena off)"), 5, || {
            plan_noarena.execute(&ins).unwrap()
        });
        let mut scratch = PlanScratch::default();
        let t_plan = time(&format!("oracle[{name}]: plan execute (arena on)"), 5, || {
            plan.execute_with_scratch(&ins, &mut scratch).unwrap()
        });
        println!(
            "{:<46} {:>9.2}x (arena) / {:.2}x (no arena)",
            "  -> plan speedup vs tree-walker",
            t_eval / t_plan,
            t_eval / t_noarena
        );

        // wave-parallel plan execution on a 4-thread pool (independent
        // steps run concurrently; numerics stay bitwise — the schedule
        // only reorders hazard-free steps)
        let plan_par = ExecutablePlan::compile_with(
            &module,
            PlanOptions { reuse_buffers: true, parallel: true },
        )
        .unwrap();
        let wave_pool = WorkerPool::new(4);
        let mut pscratch = PlanScratch::default();
        let t_waves = wave_pool.install(|| {
            time(&format!("oracle[{name}]: plan execute (waves, 4 thr)"), 5, || {
                plan_par.execute_with_scratch(&ins, &mut pscratch).unwrap()
            })
        });
        println!(
            "{:<46} {:>9.2}x ({} waves / {} steps)",
            "  -> wave-parallel speedup vs serial plan",
            t_plan / t_waves,
            plan_par.wave_count(),
            plan_par.step_count()
        );

        // batched multi-seed execution (the suite --golden-seeds path):
        // N seeds through one run_batch, sharing a single PlanScratch,
        // vs N independent run() calls each paying fresh-arena setup
        const SEEDS: usize = 8;
        let oracle = GoldenOracle::from_text(name, &text).unwrap();
        let seed_inputs: Vec<_> = (0..SEEDS as u64).map(|s| task.make_inputs(7 + s)).collect();
        let batches: Vec<Vec<&Tensor>> = seed_inputs
            .iter()
            .map(|m| task.inputs.iter().map(|(n, _, _)| &m[*n]).collect())
            .collect();
        // sanity: batch results == per-seed results, bitwise
        let batched = oracle.run_batch(&batches).unwrap();
        for (b, ins) in batched.iter().zip(&batches) {
            let single = oracle.run(ins).unwrap();
            assert_eq!(b.len(), single.len());
            for (x, y) in b.iter().zip(&single) {
                assert_eq!(x.data, y.data, "{name}: run_batch diverged from run");
            }
        }
        let t_single = time(&format!("oracle[{name}]: {SEEDS} seeds via run()"), 5, || {
            batches.iter().map(|ins| oracle.run(ins).unwrap()).collect::<Vec<_>>()
        });
        let mut bscratch = PlanScratch::default();
        let t_batch = time(&format!("oracle[{name}]: {SEEDS} seeds via run_batch"), 5, || {
            oracle.run_batch_with_scratch(&batches, &mut bscratch).unwrap()
        });
        println!(
            "{:<46} {:>9.2}x\n",
            "  -> run_batch speedup vs per-seed run",
            t_single / t_batch
        );
    }

    // 1. simulator throughput on a bandwidth-bound elementwise kernel
    let relu = task_by_name("relu").unwrap();
    let n = relu.primary_numel() as f64;
    let secs = time("sim: relu 4.2M elements end-to-end", 5, || {
        run_task(&relu, &PipelineConfig::default())
    });
    println!(
        "{:<46} {:>10.1} M modeled elements/s\n",
        "  -> simulator functional throughput",
        n / secs / 1e6
    );

    // 2. pipeline latency per task class
    for name in ["gelu", "softmax", "adam", "cumsum", "maxpool2d"] {
        let task = task_by_name(name).unwrap();
        time(&format!("pipeline: {name}"), 3, || run_task(&task, &PipelineConfig::default()));
    }
    println!();

    // 2b. pipeline group: per-stage wall time from the session's
    // StageReports, one representative task per category — the tracked
    // baseline for the staged compilation-session API's timings
    println!("pipeline stage timings (mean of {PIPELINE_ITERS} runs, ms):");
    println!(
        "{:<28} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "task", "generate", "frontend", "transpile", "analyze", "compile", "simulate", "score"
    );
    const PIPELINE_ITERS: usize = 3;
    for name in ["gelu", "mse_loss", "cumsum", "rmsnorm", "adam", "sum_dim", "maxpool2d"] {
        let task = task_by_name(name).unwrap();
        let cfg = PipelineConfig::default();
        let _ = run_task(&task, &cfg); // warmup
        // the stage list is deterministic per config, so reports line up
        // run-to-run; accumulate by position
        let mut names: Vec<&'static str> = Vec::new();
        let mut acc: Vec<f64> = Vec::new();
        for _ in 0..PIPELINE_ITERS {
            let art = run_task(&task, &cfg);
            if names.is_empty() {
                names = art.result.stage_timings.iter().map(|r| r.name).collect();
                acc = vec![0.0; names.len()];
            }
            for (slot, report) in acc.iter_mut().zip(&art.result.stage_timings) {
                *slot += report.wall_secs;
            }
        }
        let mut row = format!("{:<28}", format!("pipeline[{name}]"));
        let stages =
            ["generate", "frontend", "transpile", "analyze", "compile", "simulate", "score"];
        for stage in stages {
            match names.iter().position(|n| *n == stage) {
                Some(i) => {
                    row.push_str(&format!(" {:>9.3}", acc[i] / PIPELINE_ITERS as f64 * 1e3))
                }
                None => row.push_str(&format!(" {:>9}", "-")),
            }
        }
        println!("{row}");
    }
    println!();

    // 2c. backend group: per-task execute time of the SAME compiled
    // kernel on every registered backend — the timing simulator
    // (ascend-sim) vs the functional-only triage path (cpu-ref)
    println!("backend execute (compiled kernel reused, fresh inputs per iter):");
    let registry = BackendRegistry::builtin();
    for name in ["relu", "softmax", "adam"] {
        let task = task_by_name(name).unwrap();
        let cfg = PipelineConfig::default();
        let art = run_task(&task, &cfg);
        assert!(art.result.correct, "{name}: {:?}", art.result.failure);
        let kernel = art.session.kernel.clone().expect("compile stage produced a kernel");
        // rebuild the simulate-stage inputs: task tensors + generator scratch
        let synth = KnowledgeBaseSynthesizer::default();
        let gen = synth.generate(&task).unwrap();
        let mut inputs = task.make_inputs(cfg.seed);
        for (sname, shape) in &gen.scratch {
            inputs.insert(sname.clone(), ascendcraft::util::tensor::Tensor::zeros(shape));
        }
        let mut secs = Vec::new();
        for backend in registry.all() {
            let s = time(&format!("backend[{name}]: execute on {}", backend.name()), 5, || {
                backend.execute(&kernel, inputs.clone(), cfg.cores).expect("execute succeeds")
            });
            secs.push(s);
        }
        if let [sim_secs, cpu_secs] = secs[..] {
            println!(
                "{:<46} {:>9.2}x",
                "  -> cpu-ref speedup vs ascend-sim",
                sim_secs / cpu_secs
            );
        }
    }
    println!();

    // 3. frontend + transcompiler throughput (no simulation)
    let synth = KnowledgeBaseSynthesizer::default();
    let task = task_by_name("adam").unwrap();
    let gen = synth.generate(&task).unwrap();
    let inputs = {
        let mut m = task.make_inputs(1);
        for (name, shape) in &gen.scratch {
            m.insert(name.clone(), ascendcraft::util::tensor::Tensor::zeros(shape));
        }
        m
    };
    time("dsl: parse+validate adam program", 200, || dsl::frontend(&gen.dsl_source).unwrap());
    let program = dsl::frontend(&gen.dsl_source).unwrap();
    time("transpile: 4 passes adam program", 200, || {
        transpile(&program, &inputs, &TranspileOptions::default()).unwrap()
    });
    println!();

    // 3b. analysis group: the CFG/dataflow lint passes over the
    // transpiled IR (the analyze stage's whole cost, then the CFG
    // construction alone)
    let out = transpile(&program, &inputs, &TranspileOptions::default()).unwrap();
    let numel: std::collections::HashMap<String, usize> =
        inputs.iter().map(|(n, t)| (n.clone(), t.numel())).collect();
    let aenv = AnalyzeEnv::new(out.tiling.clone()).with_numel(numel);
    time("analysis: all passes, adam program", 200, || analyze(&out.program, &aenv));
    let first_kernel = &out.program.kernels[0];
    time("analysis: CFG build, adam kernel", 500, || Cfg::build(first_kernel));
    println!();

    // 4. worker scaling on a 12-task slice (NOTE: on a single-core host
    // this demonstrates oversubscription cost, not speedup)
    println!(
        "host parallelism: {} core(s)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    let slice: Vec<_> = [
        "relu", "gelu", "sigmoid", "silu", "mish", "softsign", "softmax", "rmsnorm", "l2norm",
        "cumsum", "sum_dim", "mse_loss",
    ]
    .iter()
    .map(|n| task_by_name(n).unwrap())
    .collect();
    let mut base = 0.0;
    let max_workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    for workers in [1usize, 2, 4, 8].into_iter().filter(|w| *w <= max_workers.max(2)) {
        let pool = WorkerPool::new(workers);
        let cfg = SuiteConfig {
            workers,
            verbose: false,
            ..Default::default()
        };
        let started = Instant::now();
        let suite = pool.install(|| run_suite(&slice, &cfg));
        let secs = started.elapsed().as_secs_f64();
        assert!(suite.totals().correct == slice.len());
        if workers == 1 {
            base = secs;
        }
        println!(
            "suite slice (12 tasks) with {workers} workers: {secs:>6.2}s  (speedup {:.2}x)",
            base / secs
        );
    }
}
