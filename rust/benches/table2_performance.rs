//! Bench E2 — regenerates paper Table 2 (Fast₀.₂/₀.₈/₁.₀ per category)
//! on the simulated 910B and reports paper-vs-measured per cell, plus the
//! per-task speedup distribution behind the percentages.
//!
//! Run: `cargo bench --bench table2_performance`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};

/// Paper Table 2 (Fast0.2, Fast0.8, Fast1.0) per category, category order.
const PAPER_TABLE2: &[(&str, f64, f64, f64)] = &[
    ("Activation", 100.0, 80.0, 40.0),
    ("Loss", 85.7, 85.7, 85.7),
    ("Math", 83.3, 66.7, 66.7),
    ("Normalization", 50.0, 37.5, 37.5),
    ("Optimizer", 100.0, 100.0, 100.0),
    ("Reduce", 100.0, 0.0, 0.0),
    ("Pooling", 50.0, 0.0, 0.0),
];
const PAPER_TOTAL: (f64, f64, f64) = (82.7, 57.7, 46.2);

fn main() {
    let tasks = all_tasks();
    let suite = run_suite(&tasks, &SuiteConfig::default());

    println!("{}", suite.render_table2());

    println!("paper vs measured (Fast0.2 | Fast0.8 | Fast1.0):");
    for ((name, p02, p08, p10), row) in PAPER_TABLE2.iter().zip(suite.by_category()) {
        let m = &row.metrics;
        println!(
            "  {:<16} paper {:>5.1} {:>5.1} {:>5.1}   ours {:>5.1} {:>5.1} {:>5.1}",
            name,
            p02,
            p08,
            p10,
            m.fast02_pct(),
            m.fast08_pct(),
            m.fast10_pct()
        );
    }
    let t = suite.totals();
    println!(
        "  {:<16} paper {:>5.1} {:>5.1} {:>5.1}   ours {:>5.1} {:>5.1} {:>5.1}",
        "Total",
        PAPER_TOTAL.0,
        PAPER_TOTAL.1,
        PAPER_TOTAL.2,
        t.fast02_pct(),
        t.fast08_pct(),
        t.fast10_pct()
    );

    println!("\nper-task speedups (eager cycles / generated cycles):");
    for r in &suite.results {
        match r.speedup() {
            Some(s) => println!("  {:<18} {:>7.2}x", r.name, s),
            None => println!("  {:<18} {:>8}", r.name, if r.compiled { "wrong" } else { "nocomp" }),
        }
    }

    // qualitative shape assertions (DESIGN.md E2): who wins must match
    let rows = suite.by_category();
    let get = |name: &str| rows.iter().find(|r| r.category.starts_with(name)).unwrap();
    // fusion-heavy categories win outright
    assert_eq!(get("Optimizer").metrics.fast10_pct(), 100.0);
    assert!(get("Loss").metrics.fast10_pct() >= 80.0);
    // tuned eager built-ins stay unbeaten
    assert_eq!(get("Reduce").metrics.fast10_pct(), 0.0);
    assert_eq!(get("Pooling").metrics.fast10_pct(), 0.0);
    assert_eq!(get("Reduce").metrics.fast08_pct(), 0.0);
    // activation Fast1.0 matches exactly (composite-eager fusion wins)
    assert_eq!(get("Activation").metrics.fast10_pct(), 40.0);
    // normalization Fast0.8/1.0 match exactly
    assert!((get("Normalization").metrics.fast10_pct() - 37.5).abs() < 0.1);
    println!("\nTable 2: qualitative shape (who wins / who loses per category) matches the paper");
}
