//! Bench E3+E5 — ablations of the design choices the paper argues for:
//!
//! * A0 direct AscendC generation (paper §2.3 motivation: ~13% correct)
//! * A1 category examples off (generic template only, §4.1)
//! * A2 compile-feedback repair off (§4.2 per-pass correction)
//! * A3 Pass 4 off, repair on (reactive padding instead of the
//!   refinement pass — repairable but blunter/slower)
//! * A4 Pass 4 off, repair off (alignment errors become Comp@1 failures)
//! * A5 double buffering off (queue depth 1: correctness unchanged,
//!   performance drops)
//!
//! Run: `cargo bench --bench ablations`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::pipeline::{PipelineConfig, PipelineMode};
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::transpile::TranspileOptions;

/// (Comp@1, Pass@1, Fast0.8, mean speedup of correct kernels)
fn run(label: &str, pipeline: PipelineConfig) -> (f64, f64, f64, f64) {
    let suite = run_suite(&all_tasks(), &SuiteConfig { pipeline, verbose: false, ..Default::default() });
    let t = suite.totals();
    let speedups: Vec<f64> = suite.results.iter().filter_map(|r| r.speedup()).collect();
    let mean = if speedups.is_empty() {
        0.0
    } else {
        (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp()
    };
    println!(
        "{:<34} Comp@1 {:>5.1}  Pass@1 {:>5.1}  Fast0.8 {:>5.1}  geomean speedup {:>5.2}x",
        label,
        t.comp_pct(),
        t.pass_pct(),
        t.fast08_pct(),
        mean
    );
    (t.comp_pct(), t.pass_pct(), t.fast08_pct(), mean)
}

fn main() {
    println!("ablations over the full 52-task suite:\n");

    let full = run("full AscendCraft", PipelineConfig::default());

    let direct = run(
        "A0 direct AscendC generation",
        PipelineConfig { mode: PipelineMode::Direct, ..Default::default() },
    );

    let generic = run(
        "A1 category examples OFF",
        PipelineConfig { mode: PipelineMode::GenericExamples, ..Default::default() },
    );

    let no_repair = run(
        "A2 compile feedback OFF",
        PipelineConfig { max_repair_rounds: 0, ..Default::default() },
    );

    let no_pass4_repair = run(
        "A3 pass 4 OFF (repair on)",
        PipelineConfig {
            options: TranspileOptions { pass4: false, ..Default::default() },
            ..Default::default()
        },
    );

    let no_pass4_no_repair = run(
        "A4 pass 4 OFF + feedback OFF",
        PipelineConfig {
            options: TranspileOptions { pass4: false, ..Default::default() },
            max_repair_rounds: 0,
            ..Default::default()
        },
    );

    let no_double_buffer = run(
        "A5 double buffering OFF",
        PipelineConfig {
            options: TranspileOptions { queue_depth: 1, ..Default::default() },
            ..Default::default()
        },
    );

    println!("\nclaims checked:");
    // direct generation collapses (paper: <=13% for the best LLM)
    assert!(direct.1 <= 15.0, "direct Pass@1 {} should collapse", direct.1);
    println!("  direct generation collapses to {:.1}% Pass@1 (paper: 13.0%)", direct.1);
    // category knowledge matters
    assert!(generic.1 < full.1 - 20.0, "generic {} vs full {}", generic.1, full.1);
    println!("  removing category examples costs {:.1} Pass@1 points", full.1 - generic.1);
    // feedback repairs real failures (UB oversubscription family)
    assert!(no_repair.0 < full.0, "repair-off Comp@1 {} vs {}", no_repair.0, full.0);
    println!("  disabling compile feedback costs {:.1} Comp@1 points", full.0 - no_repair.0);
    // pass 4 is recoverable via feedback, fatal without it
    assert!((no_pass4_repair.1 - full.1).abs() < 10.0);
    assert!(no_pass4_no_repair.0 < no_pass4_repair.0);
    println!(
        "  pass-4-off is repairable ({:.1} Comp@1) but fatal without feedback ({:.1})",
        no_pass4_repair.0, no_pass4_no_repair.0
    );
    // double buffering is a pure performance feature
    assert!((no_double_buffer.1 - full.1).abs() < 6.0, "depth-1 correctness");
    assert!(no_double_buffer.3 < full.3, "depth-1 must be slower overall");
    println!(
        "  depth-1 queues keep correctness ({:.1}) but drop geomean speedup {:.2}x -> {:.2}x",
        no_double_buffer.1, full.3, no_double_buffer.3
    );
    // reactive padding (A3) is correct but slower than the pass-4 analysis
    assert!(no_pass4_repair.3 <= full.3 + 0.02);
}
