//! Bench E4 — the RQ3 mHC numbers: generated and optimized kernel speedups
//! over eager for mHC_post and mHC_post_grad, compared with the paper's
//! 6.6x / 3.0x (generated) and 15.9x / 7.2x (optimized).
//!
//! Run: `cargo bench --bench rq3_mhc`

use ascendcraft::mhc::{run_case_study, run_case_study_paper_shapes, MhcDims};

const PAPER: &[(&str, f64)] = &[
    ("mhc_post/generated", 6.6),
    ("mhc_post/optimized", 15.9),
    ("mhc_post_grad/generated", 3.0),
    ("mhc_post_grad/optimized", 7.2),
];

fn main() {
    let (post, grad) = (MhcDims::post_default(), MhcDims::grad_default());
    println!(
        "mHC case study: n={}, d={}; post rows={}, grad rows={}\n",
        post.n, post.d, post.rows, grad.rows
    );
    let runs = run_case_study_paper_shapes(42);
    println!(
        "{:<28} {:>8} {:>12} {:>14} {:>12}",
        "variant", "correct", "cycles", "paper speedup", "ours"
    );
    for (r, (pname, pspeed)) in runs.iter().zip(PAPER) {
        assert_eq!(&r.variant, pname);
        println!(
            "{:<28} {:>8} {:>12.0} {:>13.1}x {:>11.2}x",
            r.variant, r.correct, r.cycles, pspeed, r.speedup_vs_eager
        );
        assert!(r.correct, "{}: {:?}", r.variant, r.failure);
    }

    // the paper's qualitative RQ3 claims:
    // 1. both kernels generated correct in a single pass (asserted above)
    // 2. generated kernels substantially beat eager
    for r in &runs {
        assert!(r.speedup_vs_eager > 1.5, "{} only {:.2}x", r.variant, r.speedup_vs_eager);
    }
    // 3. expert optimization roughly doubles-plus the generated speedup
    let ratio_post = runs[1].speedup_vs_eager / runs[0].speedup_vs_eager;
    let ratio_grad = runs[3].speedup_vs_eager / runs[2].speedup_vs_eager;
    println!(
        "\noptimized/generated gain: post {ratio_post:.2}x (paper {:.2}x), grad {ratio_grad:.2}x (paper {:.2}x)",
        15.9 / 6.6,
        7.2 / 3.0
    );
    assert!(ratio_post > 1.8 && ratio_grad > 1.8);

    // scaling: smaller problems are more launch-bound, widening the gap
    println!("\nspeedup vs problem size (rows sweep):");
    for rows in [512usize, 1024, 1792, 3072] {
        let d = MhcDims { rows, ..MhcDims::default() };
        let runs = run_case_study(&d, 42);
        println!(
            "  rows={rows:<5} post gen {:>5.2}x opt {:>5.2}x | grad gen {:>5.2}x opt {:>5.2}x",
            runs[0].speedup_vs_eager,
            runs[1].speedup_vs_eager,
            runs[2].speedup_vs_eager,
            runs[3].speedup_vs_eager
        );
    }
}
