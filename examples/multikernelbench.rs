//! End-to-end MultiKernelBench driver — the repository's headline
//! validation run (DESIGN.md E1+E2).
//!
//! Runs all 52 Level-1 tasks through the full AscendCraft pipeline on the
//! worker pool, verifies every kernel against host references (and the
//! checked-in HLO golden oracles, executed by the `runtime::hlo`
//! interpreter), and regenerates the paper's Table 1 and Table 2. Writes
//! a JSON report next to the binary output for EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example multikernelbench`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::service::{cross_check_suite, run_suite, SuiteConfig};
use ascendcraft::runtime::OracleRegistry;

fn main() {
    let tasks = all_tasks();
    println!("running {} tasks on {} workers ...", tasks.len(), SuiteConfig::default().workers);
    let cfg = SuiteConfig { verbose: true, ..Default::default() };
    let started = std::time::Instant::now();
    let suite = run_suite(&tasks, &cfg);
    println!("\nsuite wall-clock: {:.1}s", started.elapsed().as_secs_f64());

    println!("\n{}", suite.render_table1());
    println!("{}", suite.render_table2());

    // cross-check the rust references against the JAX golden oracles
    // for every artifact that exists (L2 <-> L3 agreement)
    let reg = OracleRegistry::default_dir();
    let artifact_names = reg.list();
    if artifact_names.is_empty() {
        println!("(no artifacts/ — restore the checked-in fixtures or run `make artifacts`)");
    } else {
        println!("golden cross-check ({} artifacts):", artifact_names.len());
        let oracle_tasks: Vec<_> = tasks
            .iter()
            .filter(|t| artifact_names.iter().any(|n| n == t.name))
            .cloned()
            .collect();
        let checks = cross_check_suite(&oracle_tasks, &reg, cfg.workers, 77);
        for c in &checks {
            println!("  {:<14} {}", c.name, if c.ok { "ok" } else { "MISMATCH" });
            assert!(c.ok, "{}: {}", c.name, c.detail);
        }
        println!("  ({} oracles agree with the rust references)", checks.len());
    }

    // persist the per-task report
    let json = suite.to_json().to_pretty();
    std::fs::write("multikernelbench_report.json", &json).expect("write report");
    println!("\nwrote multikernelbench_report.json ({} bytes)", json.len());

    // headline assertions (EXPERIMENTS.md E1): Table 1 must match the paper
    let totals = suite.totals();
    assert!((totals.comp_pct() - 98.1).abs() < 0.1, "Comp@1 {}", totals.comp_pct());
    assert!((totals.pass_pct() - 90.4).abs() < 0.1, "Pass@1 {}", totals.pass_pct());
    println!("Table 1 headline matches the paper: Comp@1 98.1, Pass@1 90.4");
}
