//! End-to-end MultiKernelBench driver — the repository's headline
//! validation run (DESIGN.md E1+E2).
//!
//! Runs all 52 Level-1 tasks through the full AscendCraft pipeline on the
//! worker pool, verifies every kernel against host references (and the
//! PJRT golden oracles where `make artifacts` has produced them), and
//! regenerates the paper's Table 1 and Table 2. Writes a JSON report next
//! to the binary output for EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example multikernelbench`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::runtime::OracleRegistry;
use ascendcraft::util::compare::allclose_report;

fn main() {
    let tasks = all_tasks();
    println!("running {} tasks on {} workers ...", tasks.len(), SuiteConfig::default().workers);
    let cfg = SuiteConfig { verbose: true, ..Default::default() };
    let started = std::time::Instant::now();
    let suite = run_suite(&tasks, &cfg);
    println!("\nsuite wall-clock: {:.1}s", started.elapsed().as_secs_f64());

    println!("\n{}", suite.render_table1());
    println!("{}", suite.render_table2());

    // cross-check the rust references against the JAX/PJRT golden oracles
    // for every artifact that exists (L2 <-> L3 agreement)
    let reg = OracleRegistry::default_dir();
    let artifact_names = reg.list();
    if artifact_names.is_empty() {
        println!("(no artifacts/ — run `make artifacts` for the PJRT golden cross-check)");
    } else {
        println!("PJRT golden cross-check ({} artifacts):", artifact_names.len());
        let mut checked = 0;
        for name in &artifact_names {
            let Some(task) = tasks.iter().find(|t| t.name == name.as_str()) else {
                continue;
            };
            let oracle = match reg.get(name) {
                Ok(o) => o,
                Err(e) => {
                    println!("  {name:<14} load failed: {e}");
                    continue;
                }
            };
            let inputs = task.make_inputs(77);
            let ins: Vec<_> = task.inputs.iter().map(|(n, _, _)| &inputs[*n]).collect();
            let want = task.reference(&inputs);
            let got = oracle.run(&ins).expect("oracle run");
            let rep = allclose_report(&got[0], &want[task.outputs[0].0], 1e-3, 1e-4);
            println!("  {name:<14} {}", if rep.ok { "ok" } else { "MISMATCH" });
            assert!(rep.ok, "{name}: {}", rep.summary());
            checked += 1;
        }
        println!("  ({checked} oracles agree with the rust references)");
    }

    // persist the per-task report
    let json = suite.to_json().to_pretty();
    std::fs::write("multikernelbench_report.json", &json).expect("write report");
    println!("\nwrote multikernelbench_report.json ({} bytes)", json.len());

    // headline assertions (EXPERIMENTS.md E1): Table 1 must match the paper
    let totals = suite.totals();
    assert!((totals.comp_pct() - 98.1).abs() < 0.1, "Comp@1 {}", totals.comp_pct());
    assert!((totals.pass_pct() - 90.4).abs() < 0.1, "Pass@1 {}", totals.pass_pct());
    println!("Table 1 headline matches the paper: Comp@1 98.1, Pass@1 90.4");
}
