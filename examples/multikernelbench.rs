//! End-to-end MultiKernelBench driver — the repository's headline
//! validation run (DESIGN.md E1+E2).
//!
//! Runs all 52 Level-1 tasks through the full AscendCraft pipeline on the
//! worker pool, verifies every kernel against host references (and the
//! checked-in HLO golden oracles, executed by the `runtime::hlo`
//! interpreter), and regenerates the paper's Table 1 and Table 2. Writes
//! a JSON report next to the binary output for EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example multikernelbench`

use ascendcraft::bench_suite::tasks::all_tasks;
use ascendcraft::coordinator::service::{run_suite, SuiteConfig};
use ascendcraft::runtime::OracleRegistry;
use std::sync::Arc;

fn main() {
    let tasks = all_tasks();
    println!("running {} tasks on {} workers ...", tasks.len(), SuiteConfig::default().workers);
    // the golden L2<->L3 cross-check runs inside the suite itself: each
    // worker checks its task against the compiled HLO oracle right after
    // the pipeline run (SuiteConfig::golden / `ascendcraft suite --golden`)
    let cfg = SuiteConfig {
        verbose: true,
        golden: Some(Arc::new(OracleRegistry::default_dir())),
        ..Default::default()
    };
    let started = std::time::Instant::now();
    let suite = run_suite(&tasks, &cfg);
    println!("\nsuite wall-clock: {:.1}s", started.elapsed().as_secs_f64());

    println!("\n{}", suite.render_table1());
    println!("{}", suite.render_table2());

    println!(
        "golden cross-check: {} artifacts checked in-suite, {} failed",
        suite.golden_checked(),
        suite.golden_failures().len()
    );
    for r in suite.golden_failures() {
        let g = r.golden.as_ref().unwrap();
        println!("  {:<14} MISMATCH: {}", r.name, g.detail);
    }
    assert!(suite.golden_failures().is_empty(), "L2<->L3 golden cross-check failed");

    // persist the per-task report
    let json = suite.to_json().to_pretty();
    std::fs::write("multikernelbench_report.json", &json).expect("write report");
    println!("\nwrote multikernelbench_report.json ({} bytes)", json.len());

    // headline assertions (EXPERIMENTS.md E1): Table 1 must match the paper
    let totals = suite.totals();
    assert!((totals.comp_pct() - 98.1).abs() < 0.1, "Comp@1 {}", totals.comp_pct());
    assert!((totals.pass_pct() - 90.4).abs() < 0.1, "Pass@1 {}", totals.pass_pct());
    println!("Table 1 headline matches the paper: Comp@1 98.1, Pass@1 90.4");
}
