//! Codegen-service demo: AscendCraft as a deployed kernel-generation
//! service (the L3 coordinator's intended shape).
//!
//! A client thread submits kernel requests (task specs) to a bounded job
//! queue; a worker pool drains it, running the full generation pipeline
//! per request and returning verified AscendC plus a report. Demonstrates
//! concurrency, per-request artifacts, and failure reporting for
//! unsupported requests (the bool-dtype kernel).
//!
//! Run: `cargo run --release --example serve_codegen`

use ascendcraft::bench_suite::tasks::task_by_name;
use ascendcraft::coordinator::pipeline::{run_task, PipelineConfig};
use std::sync::mpsc;
use std::time::Instant;

struct Request {
    id: usize,
    task_name: &'static str,
}

struct Response {
    id: usize,
    task_name: &'static str,
    ok: bool,
    detail: String,
    ascendc_lines: usize,
    secs: f64,
}

fn main() {
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let req_rx = std::sync::Arc::new(std::sync::Mutex::new(req_rx));

    let workers = 4;
    std::thread::scope(|scope| {
        // worker pool
        for worker_id in 0..workers {
            let req_rx = std::sync::Arc::clone(&req_rx);
            let resp_tx = resp_tx.clone();
            scope.spawn(move || loop {
                let req = {
                    let guard = req_rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { return };
                let started = Instant::now();
                let task = task_by_name(req.task_name).expect("known task");
                let art = run_task(&task, &PipelineConfig::default());
                let ascendc_lines = art
                    .program()
                    .map(|p| ascendcraft::ascendc::print_ascendc(p).lines().count())
                    .unwrap_or(0);
                let _ = resp_tx.send(Response {
                    id: req.id,
                    task_name: req.task_name,
                    ok: art.result.correct,
                    detail: art
                        .result
                        .failure
                        .as_ref()
                        .map(|d| d.to_string())
                        .unwrap_or_else(|| {
                            format!(
                                "verified, {:.2}x vs eager, {} repair rounds (worker {worker_id})",
                                art.result.speedup().unwrap_or(0.0),
                                art.result.repair_rounds
                            )
                        }),
                    ascendc_lines,
                    secs: started.elapsed().as_secs_f64(),
                });
            });
        }
        drop(resp_tx);

        // client: submit a mixed batch of requests, including one the
        // service must reject (bool mask kernel)
        let batch = [
            "relu", "gelu", "softmax", "adam", "cumsum", "mse_loss", "mask_cumsum", "l2norm",
        ];
        for (id, name) in batch.iter().enumerate() {
            req_tx.send(Request { id, task_name: name }).unwrap();
        }
        drop(req_tx);

        let mut responses: Vec<Response> = resp_rx.iter().collect();
        responses.sort_by_key(|r| r.id);
        println!("{:<4} {:<14} {:<6} {:>8} {:>7}  detail", "id", "kernel", "ok", "ascendc", "secs");
        let mut ok_count = 0;
        for r in &responses {
            println!(
                "{:<4} {:<14} {:<6} {:>8} {:>6.2}s  {}",
                r.id,
                r.task_name,
                r.ok,
                r.ascendc_lines,
                r.secs,
                &r.detail[..r.detail.len().min(80)]
            );
            ok_count += r.ok as usize;
        }
        assert_eq!(responses.len(), batch.len());
        assert_eq!(ok_count, batch.len() - 1, "exactly mask_cumsum should fail");
        println!("\nserved {} requests, {} verified kernels", responses.len(), ok_count);
    });
}
