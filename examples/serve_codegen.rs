//! Codegen-service demo: AscendCraft as a deployed kernel-generation
//! service — now a thin client of the real `serve` subsystem instead of
//! an ad-hoc mpsc worker pool.
//!
//! Spawns the daemon in-process ([`Daemon::start`]), submits a mixed
//! batch of kernel requests through the same [`KernelRequest`] objects
//! the JSONL wire protocol parses into — including one the service must
//! reject (the bool-dtype `mask_cumsum` kernel) — then replays the batch
//! to show the content-addressed cache: every warm response is a hit and
//! carries the byte-identical verdict with no pipeline stages run.
//!
//! Run: `cargo run --release --example serve_codegen`

use ascendcraft::serve::{Daemon, KernelRequest, Response, ServeConfig};

/// The demo batch: seven kernels the service verifies end-to-end plus
/// `mask_cumsum`, whose bool dtype the transpiler rejects (`ok` stays
/// true — the request was *served*; the verdict lives in the result).
const BATCH: [&str; 8] =
    ["relu", "gelu", "softmax", "adam", "cumsum", "mse_loss", "mask_cumsum", "l2norm"];

fn submit_batch(daemon: &Daemon) -> Vec<Response> {
    let tickets: Vec<_> = BATCH
        .iter()
        .enumerate()
        .map(|(id, name)| {
            let mut req = KernelRequest::new(name);
            req.id = id as u64;
            daemon.submit(req)
        })
        .collect();
    let mut responses: Vec<Response> = tickets.into_iter().map(|t| t.wait()).collect();
    responses.sort_by_key(|r| r.id);
    responses
}

fn print_batch(phase: &str, responses: &[Response]) -> usize {
    println!(
        "{:<4} {:<14} {:<8} {:<6} {:>7}  detail",
        "id", "kernel", "verdict", "cache", "secs"
    );
    let mut correct = 0;
    for r in responses {
        let result = r.result.as_ref().expect("served request carries a result");
        let verdict = if result.correct {
            "pass"
        } else if result.compiled {
            "wrong"
        } else {
            "nocompile"
        };
        correct += usize::from(result.correct);
        let detail = match &result.failure {
            Some(d) => d.to_string(),
            None => format!(
                "verified, {:.2}x vs eager, {} repair rounds",
                result.speedup().unwrap_or(0.0),
                result.repair_rounds
            ),
        };
        println!(
            "{:<4} {:<14} {:<8} {:<6} {:>6.2}s  {}",
            r.id,
            result.name,
            verdict,
            if r.cache_hit {
                "hit"
            } else if r.coalesced {
                "join"
            } else {
                "miss"
            },
            r.secs,
            &detail[..detail.len().min(80)]
        );
    }
    println!("  ({phase} pass: {correct}/{} verified)\n", responses.len());
    correct
}

fn main() {
    let daemon = Daemon::start(ServeConfig { workers: 4, ..ServeConfig::default() })
        .expect("daemon starts");

    // cold pass: every request is a miss and runs the full pipeline
    let cold = submit_batch(&daemon);
    let cold_ok = print_batch("cold", &cold);
    assert_eq!(cold.len(), BATCH.len());
    assert!(cold.iter().all(|r| r.ok), "every request must be served, not rejected");
    assert!(cold.iter().all(|r| !r.cache_hit), "first pass must not hit the cache");
    assert_eq!(cold_ok, BATCH.len() - 1, "exactly mask_cumsum should fail");

    // warm pass: the same batch again — all cache hits, identical verdicts
    let warm = submit_batch(&daemon);
    let warm_ok = print_batch("warm", &warm);
    assert_eq!(warm_ok, cold_ok);
    for (c, w) in cold.iter().zip(&warm) {
        assert!(w.cache_hit, "repeat request {} must be a cache hit", w.id);
        assert_eq!(
            c.result, w.result,
            "cached verdict must be identical to the executed one"
        );
    }

    let stats = daemon.shutdown();
    println!("{}", stats.render());
    assert_eq!(stats.cache.executed, BATCH.len(), "each tuple ran the pipeline exactly once");
    assert_eq!(stats.cache.hits, BATCH.len(), "the warm pass was served entirely from cache");
    println!(
        "served {} requests, {} verified kernels, hit rate {:.0}%",
        stats.requests,
        warm_ok,
        stats.hit_rate().unwrap_or(0.0) * 100.0
    );
}
