//! RQ3 case study driver: the mHC kernels (paper §5.4).
//!
//! Generates AscendC for `mHC_post` and `mHC_post_grad` (novel kernels
//! outside the benchmark), verifies against host references, and compares
//! three execution paths — eager, generated, expert-optimized — at the
//! default case-study shapes. The checked-in golden artifacts are
//! additionally cross-checked against the JAX references via the HLO
//! interpreter (at the artifacts' own oracle shape).
//!
//! Run: `cargo run --release --example mhc_casestudy`

use ascendcraft::mhc::{
    self, eager_cycles, eager_grad_ops, eager_post_ops, run_case_study_paper_shapes, MhcDims,
};
use ascendcraft::runtime::OracleRegistry;

fn main() {
    let dims = MhcDims::default();
    let (post, grad) = (MhcDims::post_default(), MhcDims::grad_default());
    println!(
        "mHC case study: n={} streams, d={}; post rows={}, grad rows={}",
        dims.n, dims.d, post.rows, grad.rows
    );
    println!(
        "eager baselines: post={:.0} cycles ({} launches), grad={:.0} cycles ({} launches)\n",
        eager_cycles(&eager_post_ops(&post)),
        eager_post_ops(&post).len(),
        eager_cycles(&eager_grad_ops(&grad)),
        eager_grad_ops(&grad).len(),
    );

    let runs = run_case_study_paper_shapes(42);
    println!("{:<28} {:>8} {:>14} {:>10}", "variant", "correct", "cycles", "speedup");
    for r in &runs {
        println!(
            "{:<28} {:>8} {:>14.0} {:>9.2}x",
            r.variant, r.correct, r.cycles, r.speedup_vs_eager
        );
        assert!(r.correct, "{}: {:?}", r.variant, r.failure);
    }

    // the paper's qualitative claims must hold:
    // generated kernels beat eager; optimized beats generated substantially
    let (pg, po, gg, go) = (&runs[0], &runs[1], &runs[2], &runs[3]);
    assert!(pg.speedup_vs_eager > 1.5, "generated post should beat eager");
    assert!(gg.speedup_vs_eager > 1.5, "generated grad should beat eager");
    assert!(po.speedup_vs_eager > 1.8 * pg.speedup_vs_eager, "optimized post gains");
    assert!(go.speedup_vs_eager > 1.8 * gg.speedup_vs_eager, "optimized grad gains");

    // golden cross-check (the artifacts are checked in): the JAX mHC
    // references and the Rust reference must agree. Dims come from the
    // artifact itself — fixtures are lowered at an oracle shape smaller
    // than the case-study shape so interpreter runs stay fast.
    let reg = OracleRegistry::default_dir();
    for name in ["mhc_post", "mhc_post_grad"] {
        if !reg.available(name) {
            println!("\n({name}: no artifact — run `make artifacts`)");
            continue;
        }
        mhc::golden_cross_check(&reg, name, 42, 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("{name} golden mismatch: {e}"));
        println!("golden cross-check: {name} JAX reference == rust reference");
    }
}
