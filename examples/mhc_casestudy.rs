//! RQ3 case study driver: the mHC kernels (paper §5.4).
//!
//! Generates AscendC for `mHC_post` and `mHC_post_grad` (novel kernels
//! outside the benchmark), verifies against host references, and compares
//! three execution paths — eager, generated, expert-optimized — at the
//! default case-study shapes. When `make artifacts` has been run, the
//! simulator outputs are additionally cross-checked against the JAX/Pallas
//! golden oracles.
//!
//! Run: `cargo run --release --example mhc_casestudy`

use ascendcraft::mhc::{
    self, eager_cycles, eager_grad_ops, eager_post_ops, run_case_study_paper_shapes, MhcDims,
};
use ascendcraft::runtime::OracleRegistry;
use ascendcraft::util::compare::allclose_report;

fn main() {
    let dims = MhcDims::default();
    let (post, grad) = (MhcDims::post_default(), MhcDims::grad_default());
    println!(
        "mHC case study: n={} streams, d={}; post rows={}, grad rows={}",
        dims.n, dims.d, post.rows, grad.rows
    );
    println!(
        "eager baselines: post={:.0} cycles ({} launches), grad={:.0} cycles ({} launches)\n",
        eager_cycles(&eager_post_ops(&post)),
        eager_post_ops(&post).len(),
        eager_cycles(&eager_grad_ops(&grad)),
        eager_grad_ops(&grad).len(),
    );

    let runs = run_case_study_paper_shapes(42);
    println!("{:<28} {:>8} {:>14} {:>10}", "variant", "correct", "cycles", "speedup");
    for r in &runs {
        println!(
            "{:<28} {:>8} {:>14.0} {:>9.2}x",
            r.variant, r.correct, r.cycles, r.speedup_vs_eager
        );
        assert!(r.correct, "{}: {:?}", r.variant, r.failure);
    }

    // the paper's qualitative claims must hold:
    // generated kernels beat eager; optimized beats generated substantially
    let (pg, po, gg, go) = (&runs[0], &runs[1], &runs[2], &runs[3]);
    assert!(pg.speedup_vs_eager > 1.5, "generated post should beat eager");
    assert!(gg.speedup_vs_eager > 1.5, "generated grad should beat eager");
    assert!(po.speedup_vs_eager > 1.8 * pg.speedup_vs_eager, "optimized post gains");
    assert!(go.speedup_vs_eager > 1.8 * gg.speedup_vs_eager, "optimized grad gains");

    // PJRT golden cross-check (when artifacts are built): the Pallas mHC
    // kernels and the Rust reference must agree
    let reg = OracleRegistry::default_dir();
    if reg.available("mhc_post") {
        let inputs = mhc::make_inputs(&dims, 42, false);
        let want = mhc::reference::post_reference(&dims, &inputs);
        let oracle = reg.get("mhc_post").expect("load mhc_post oracle");
        let got = oracle
            .run(&[&inputs["h"], &inputs["w"], &inputs["g"]])
            .expect("run mhc_post oracle");
        let rep = allclose_report(&got[0], &want, 1e-3, 1e-4);
        assert!(rep.ok, "mhc_post golden mismatch: {}", rep.summary());
        println!("\nPJRT golden cross-check: mhc_post Pallas kernel == rust reference");
    } else {
        println!("\n(run `make artifacts` for the Pallas/PJRT golden cross-check)");
    }
    if reg.available("mhc_post_grad") {
        let inputs = mhc::make_inputs(&dims, 42, true);
        let want = mhc::reference::post_grad_reference(&dims, &inputs);
        let oracle = reg.get("mhc_post_grad").expect("load mhc_post_grad oracle");
        let got = oracle
            .run(&[&inputs["h"], &inputs["w"], &inputs["g"], &inputs["dy"]])
            .expect("run mhc_post_grad oracle");
        let rep = allclose_report(&got[0], &want, 1e-3, 1e-4);
        assert!(rep.ok, "mhc_post_grad golden mismatch: {}", rep.summary());
        println!("PJRT golden cross-check: mhc_post_grad Pallas kernel == rust reference");
    }
}
