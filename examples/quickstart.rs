//! Quickstart: generate, compile, verify and time one kernel end-to-end.
//!
//! Walks a single task (the paper's Figure-2 softmax) through every stage
//! of the public API, printing the intermediate artifacts:
//!
//! 1. prompt assembly (DSL spec + category expert examples),
//! 2. DSL generation (the knowledge-base synthesizer),
//! 3. DSL frontend validation,
//! 4. four-pass transcompilation to AscendC (+ compile diagnostics),
//! 5. NPU simulation: numerics vs the reference + modeled cycles,
//! 6. comparison against the PyTorch-eager baseline cost.
//!
//! Run: `cargo run --release --example quickstart`

use ascendcraft::ascendc::print_ascendc;
use ascendcraft::baselines::eager::eager_cycles;
use ascendcraft::bench_suite::tasks::task_by_name;
use ascendcraft::coordinator::pipeline::{run_task, PipelineConfig};
use ascendcraft::synth::prompt::build_prompt;

fn main() {
    let task = task_by_name("softmax").expect("softmax task");

    println!("=== 1. prompt (what a real-LLM deployment would send) ===");
    let p = build_prompt(&task);
    for line in p.lines().take(12) {
        println!("  {line}");
    }
    println!("  ... ({} more lines)\n", p.lines().count().saturating_sub(12));

    println!("=== 2-5. full pipeline ===");
    let art = run_task(&task, &PipelineConfig::default());

    println!("--- generated DSL (paper Fig. 2 structure) ---");
    let dsl = art.dsl_source().unwrap_or("(none)");
    for line in dsl.lines().take(24) {
        println!("  {line}");
    }
    println!("  ... ({} more lines)\n", dsl.lines().count().saturating_sub(24));

    println!("--- transcompiled AscendC (passes 1-4) ---");
    if let Some(program) = art.program() {
        let text = print_ascendc(program);
        for line in text.lines().take(28) {
            println!("  {line}");
        }
        println!("  ... ({} more lines)\n", text.lines().count().saturating_sub(28));
    }

    println!("=== 6. result ===");
    let r = &art.result;
    println!("  compiled (Comp@1):     {}", r.compiled);
    println!("  correct  (Pass@1):     {}", r.correct);
    println!("  repair rounds:         {}", r.repair_rounds);
    println!("  generated cycles:      {:.0}", r.generated_cycles.unwrap_or(f64::NAN));
    println!("  eager baseline cycles: {:.0}", eager_cycles(&task));
    println!("  speedup vs eager:      {:.2}x", r.speedup().unwrap_or(0.0));
    println!("  stage timings:");
    for st in &r.stage_timings {
        println!("    {:<10} {:>9.3} ms  {}", st.name, st.wall_secs * 1e3, st.outcome.name());
    }
    assert!(r.correct, "quickstart kernel must verify: {:?}", r.failure);
}
