# AscendCraft reproduction — build / test / bench entry points.
#
# The Rust crate is hermetic (zero external crates); `make artifacts`
# additionally regenerates the golden-oracle HLO fixtures from JAX when a
# Python+JAX toolchain is available, and is a no-op otherwise (the
# fixtures under artifacts/ are checked in, so tests never depend on it).

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test bench bench-snapshot bench-regress smoke regress resume-smoke serve-smoke tune-smoke artifacts doc fmt clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench: build
	$(CARGO) bench

# Re-measure the perf trajectory: runs the hotpath bench's kernel groups
# (matmul naive-vs-tiled, elementwise/reduction thread scaling), the
# serve cold-vs-warm cache replay, and the tune search-loop timing, and
# rewrites BENCH_PR10.json at the repo root. The bench self-validates
# the snapshot (reparse + required groups) and exits non-zero on a
# malformed file. Add BENCH_QUICK=1 for the reduced-size CI variant.
bench-snapshot:
	$(CARGO) bench --bench hotpath -- $(if $(BENCH_QUICK),--quick) --json BENCH_PR10.json

# Perf regression gate: re-measure a full-mode snapshot into target/ and
# diff its speedup RATIOS against the checked-in BENCH_PR10.json (raw ms
# medians are host-dependent; ratios are not). The wide tolerance absorbs
# run-to-run jitter — this gate exists to catch a tiling/threading/cache
# collapse, not a 10% wobble. Full mode only: quick mode measures smaller
# matmul shapes, so its metric names would read as missing (= regressed).
bench-regress: build
	$(CARGO) bench --bench hotpath -- --json target/BENCH_CURRENT.json
	./target/release/ascendcraft suite \
		--compare BENCH_PR10.json --bench target/BENCH_CURRENT.json \
		--tolerance 0.35

# Release-mode end-to-end smoke over a small task subset with the golden
# cross-check folded in: exercises the staged pipeline, the suite runner,
# and the L2<->L3 oracle path beyond what unit tests cover. --backend all
# shards the tasks across every registered backend (ascend-sim + cpu-ref)
# in one worker pool; --min-pass asserts the Pass@1 floor PER BACKEND so
# a silently-broken pipeline — or a diverging backend — cannot look green.
# The lint sweep then runs the static analyzer across all 52 tasks and
# fails on any analyzer error: the transpiler must stay analyzer-clean.
smoke: build
	./target/release/ascendcraft suite --quiet --golden --backend all \
		--tasks relu,gelu,softmax,mse_loss,adam --min-pass 5
	./target/release/ascendcraft lint --all

# Regression gate: run the smoke tasks on every backend and diff the
# metrics and per-task verdicts against the checked-in baseline. The
# baseline is hand-authored conservatively (verdicts only, no cycle
# counts), so Fast@1 can only improve; any Comp@1/Pass@1 drop or a
# compiled/correct verdict flipping true->false exits 1. Update
# BASELINE_SMOKE.json deliberately when the expected verdicts change.
regress: build
	./target/release/ascendcraft suite --quiet --backend all \
		--tasks relu,gelu,softmax,mse_loss,adam \
		--compare BASELINE_SMOKE.json

# Kill/resume smoke: start a serial journaled run over a mid-size task
# subset, kill it hard after 2 seconds (SIGKILL — no chance to clean
# up, exactly the failure --resume exists for), then resume from the
# journal's durable prefix and require the resumed run to finish green
# with the same Pass@1 floor as `make smoke`. The || true swallows the
# kill's exit status; the resume run is the assertion. (If the first
# run beats the timeout, the resume degenerates to a pure replay — the
# gate still holds.)
RESUME_TASKS = relu,gelu,softsign,tanh_act,sigmoid,relu6,softmax,mse_loss,adam

resume-smoke: build
	rm -f target/resume-smoke.jsonl
	timeout -s KILL 2 ./target/release/ascendcraft suite --quiet \
		--workers 1 --tasks $(RESUME_TASKS) \
		--journal target/resume-smoke.jsonl || true
	./target/release/ascendcraft suite --quiet \
		--tasks $(RESUME_TASKS) \
		--resume target/resume-smoke.jsonl --min-pass 5
	rm -f target/resume-smoke.jsonl

# Serve smoke: boot the daemon twice over one persistent cache file.
# The first invocation executes relu through the full pipeline and
# appends it to the cache; the second must answer the same request with
# "cache_hit":true WITHOUT running any pipeline stages — the restart-
# warmth acceptance criterion, end to end over the real stdio protocol.
# --workers 1 keeps the replay deterministic (no coalescing window).
serve-smoke: build
	rm -f target/serve-smoke-cache.jsonl
	printf '%s\n' \
		'{"op":"generate","id":1,"task":"relu"}' \
		'{"op":"shutdown","id":2}' \
	| ./target/release/ascendcraft serve --stdio --workers 1 \
		--cache target/serve-smoke-cache.jsonl \
	| grep -q '"ok":true'
	printf '%s\n' \
		'{"op":"generate","id":1,"task":"relu"}' \
		'{"op":"shutdown","id":2}' \
	| ./target/release/ascendcraft serve --stdio --workers 1 \
		--cache target/serve-smoke-cache.jsonl \
	| grep -q '"cache_hit":true'
	rm -f target/serve-smoke-cache.jsonl

# Tune smoke: autotune the smoke-task subset with a tiny budget into a
# throwaway store, then re-run the suite under that store. `suite --tuned`
# runs the untuned baseline AND the tuned configs in one invocation,
# prints the delta table, and exits 1 if any metric or per-task verdict
# regresses — that exit code IS the "tuning never breaks correctness"
# assertion. --min-pass keeps the Pass@1 floor identical to `make smoke`.
tune-smoke: build
	rm -f target/tune-smoke-store.jsonl
	./target/release/ascendcraft tune \
		--tasks relu,gelu,softmax,mse_loss,adam --budget 8 \
		--store target/tune-smoke-store.jsonl
	./target/release/ascendcraft suite --quiet \
		--tasks relu,gelu,softmax,mse_loss,adam \
		--tuned target/tune-smoke-store.jsonl --min-pass 5
	rm -f target/tune-smoke-store.jsonl

# Build the API docs with warnings denied (same gate as CI): broken
# intra-doc links fail instead of rotting silently.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Regenerate artifacts/*.hlo.txt from python/compile/aot.py. Skipped (with
# a note) when JAX is not importable — the checked-in fixtures remain.
artifacts:
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
		cd python && $(PYTHON) -m compile.aot; \
	else \
		echo "JAX not available; keeping checked-in artifacts/*.hlo.txt"; \
	fi

fmt:
	$(CARGO) fmt --all

clean:
	$(CARGO) clean
