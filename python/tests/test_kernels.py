"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core build-time correctness signal (interpret=True on CPU).
Shape/seed sweeps play the role of hypothesis-style property tests.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ------------------------------------------------------------------ softmax

SOFTMAX_SHAPES = [(8, 128), (16, 1024), (64, 2048), (8, 4096), (3, 256)]


@pytest.mark.parametrize("shape", SOFTMAX_SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_softmax_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, *shape)
    got = pk.softmax(x, col_tile=min(128, shape[1]))
    want = ref.softmax_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(7)
    x = rand(rng, 16, 512)
    got = pk.softmax(x, col_tile=128)
    np.testing.assert_allclose(np.sum(got, axis=-1), np.ones(16), rtol=1e-5)


def test_softmax_is_stable_for_large_logits():
    # the kernel's 3-pass max-rescale must survive the inputs that break
    # the knowledge-gapped cross_entropy kernel (scale-30 logits)
    rng = np.random.default_rng(3)
    x = 30.0 * rand(rng, 8, 256)
    got = pk.softmax(x, col_tile=128)
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-4, atol=1e-6)


def test_softmax_column_tiling_is_invisible():
    rng = np.random.default_rng(5)
    x = rand(rng, 8, 1024)
    a = pk.softmax(x, col_tile=128)
    b = pk.softmax(x, col_tile=1024)
    np.testing.assert_allclose(a, b, rtol=1e-6)


# --------------------------------------------------------------------- adam


@pytest.mark.parametrize("n", [1 << 12, 1 << 16])
@pytest.mark.parametrize("seed", [0, 2])
def test_adam_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, n)
    g = rand(rng, n)
    m = rand(rng, n)
    v = jnp.abs(rand(rng, n))
    got = pk.adam_step(p, g, m, v, tile=min(4096, n))
    want = ref.adam_ref(p, g, m, v)
    for got_t, want_t in zip(got, want):
        np.testing.assert_allclose(got_t, want_t, rtol=1e-6, atol=1e-7)


def test_adam_zero_grad_decays_moment_only():
    n = 4096
    rng = np.random.default_rng(1)
    p = rand(rng, n)
    m = rand(rng, n)
    v = jnp.abs(rand(rng, n))
    p2, m2, v2 = pk.adam_step(p, jnp.zeros(n), m, v, tile=n)
    np.testing.assert_allclose(m2, 0.9 * m, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.999 * v, rtol=1e-6)
    assert not np.allclose(p2, p)  # momentum still moves params


# ---------------------------------------------------------------------- mhc

MHC_SHAPES = [(4, 8, 128), (4, 32, 256), (2, 16, 512)]


@pytest.mark.parametrize("shape", MHC_SHAPES)
def test_mhc_post_matches_ref(shape):
    n, rows, d = shape
    rng = np.random.default_rng(11)
    h = rand(rng, n, rows, d)
    w = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n)).astype(np.float32))
    g = jnp.asarray(rng.uniform(0.5, 1.5, (n,)).astype(np.float32))
    got = pk.mhc_post(h, w, g)
    want = ref.mhc_post_ref(h, w, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", MHC_SHAPES)
def test_mhc_post_grad_matches_ref(shape):
    n, rows, d = shape
    rng = np.random.default_rng(13)
    h = rand(rng, n, rows, d)
    w = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n)).astype(np.float32))
    g = jnp.asarray(rng.uniform(0.5, 1.5, (n,)).astype(np.float32))
    dy = rand(rng, n, rows, d)
    got = pk.mhc_post_grad(h, w, g, dy)
    want = ref.mhc_post_grad_ref(h, w, g, dy)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mhc_grad_matches_jax_autodiff():
    """The hand-derived VJP must agree with jax.vjp through the reference
    (with stop_gradient on the Sinkhorn projection)."""
    import jax

    n, rows, d = 2, 4, 64
    rng = np.random.default_rng(17)
    h = rand(rng, n, rows, d)
    w = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n)).astype(np.float32))
    g = jnp.asarray(rng.uniform(0.5, 1.5, (n,)).astype(np.float32))
    dy = rand(rng, n, rows, d)

    def fwd(hh):
        p = jax.lax.stop_gradient(ref.sinkhorn_ref(w))
        m = jnp.einsum("ji,jrd->ird", p, hh)
        inv = 1.0 / jnp.sqrt(jnp.mean(m * m, axis=-1, keepdims=True) + ref.EPS)
        return hh + g[:, None, None] * m * inv

    _, vjp = jax.vjp(fwd, h)
    (want,) = vjp(dy)
    got = ref.mhc_post_grad_ref(h, w, g, dy)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sinkhorn_is_doubly_stochastic():
    rng = np.random.default_rng(19)
    w = jnp.asarray(rng.uniform(-1, 1, (4, 4)).astype(np.float32))
    p = ref.sinkhorn_ref(w, iters=8)
    np.testing.assert_allclose(np.sum(p, axis=1), np.ones(4), rtol=1e-3)
    np.testing.assert_allclose(np.sum(p, axis=0), np.ones(4), rtol=1e-3)
