"""L2 correctness: the model.py reference library vs independent formulas,
plus AOT-manifest sanity (every op lowers to parseable HLO text)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.aot import to_hlo_text


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def test_ops_manifest_covers_showcase():
    for name in ["softmax", "adam", "mhc_post", "mhc_post_grad", "gelu", "layernorm"]:
        assert name in model.OPS


def test_relu_and_gelu():
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 64)
    np.testing.assert_array_equal(model.relu(x)[0], jnp.maximum(x, 0))
    g = model.gelu(x)[0]
    # tanh-approx gelu is within 1e-3 of exact gelu
    exact = 0.5 * x * (1.0 + jax.scipy.special.erf(x / np.sqrt(2.0)))
    np.testing.assert_allclose(g, exact, atol=2e-3)


def test_layernorm_normalizes():
    rng = np.random.default_rng(1)
    x = rand(rng, 16, 128)
    y = model.layernorm(x, jnp.ones(128), jnp.zeros(128))[0]
    np.testing.assert_allclose(np.mean(y, axis=-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(np.var(y, axis=-1), np.ones(16), rtol=1e-2)


def test_softmax_through_pallas_matches_oracle():
    rng = np.random.default_rng(2)
    x = rand(rng, 16, 2048)
    np.testing.assert_allclose(
        model.softmax(x)[0], model.softmax_ref(x), rtol=1e-5, atol=1e-6
    )


def test_mse_loss_scalar_shape():
    rng = np.random.default_rng(3)
    p = rand(rng, 8, 16)
    t = rand(rng, 8, 16)
    out = model.mse_loss(p, t)[0]
    assert out.shape == (1,)
    np.testing.assert_allclose(out[0], np.mean((np.asarray(p) - np.asarray(t)) ** 2), rtol=1e-6)


def test_cumsum_and_logsumexp():
    rng = np.random.default_rng(4)
    x = rand(rng, 4, 32)
    np.testing.assert_allclose(model.cumsum(x)[0], np.cumsum(x, axis=-1), rtol=1e-5, atol=1e-6)
    want = jax.scipy.special.logsumexp(x, axis=-1)
    np.testing.assert_allclose(model.logsumexp(x)[0], want, rtol=1e-5)


@pytest.mark.parametrize("name", ["relu", "softmax", "mse_loss", "sum_dim"])
def test_ops_lower_to_hlo_text(name):
    fn, args = model.OPS[name]
    # lower with tiny stand-in shapes to keep the test fast
    small = [jax.ShapeDtypeStruct(tuple(min(d, 64) for d in a.shape), a.dtype) for a in args]
    lowered = jax.jit(fn).lower(*small)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_all_ops_are_jittable():
    # trace (no execution) every manifest entry at reduced shapes
    for name, (fn, args) in model.OPS.items():
        small = []
        for a in args:
            shape = tuple(min(d, 8) if d > 8 else d for d in a.shape)
            small.append(jax.ShapeDtypeStruct(shape, a.dtype))
        jax.jit(fn).lower(*small)  # raises on trace errors
