"""Layer-1 Pallas kernels + their pure-jnp oracles."""
