"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package is checked against these functions by
pytest before its surrounding computation is AOT-lowered for the Rust
runtime. Keep these boring and obviously correct.
"""

import jax.numpy as jnp

EPS = 1e-5


def softmax_ref(x):
    """Row-wise numerically-stable softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def adam_ref(param, grad, m, v, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One fused Adam step (no bias correction, matching the Rust task)."""
    m_new = b1 * m + (1.0 - b1) * grad
    v_new = b2 * v + (1.0 - b2) * grad * grad
    param_new = param - lr * m_new / (jnp.sqrt(v_new) + eps)
    return param_new, m_new, v_new


def sinkhorn_ref(w, iters=5):
    """Project exp(w) onto the doubly-stochastic manifold."""
    p = jnp.exp(w)
    for _ in range(iters):
        p = p / jnp.sum(p, axis=1, keepdims=True)
        p = p / jnp.sum(p, axis=0, keepdims=True)
    return p


def mhc_post_ref(h, w, g, iters=5):
    """mHC post-merge: Y[i] = H[i] + g[i] * rmsnorm(sum_j P[j,i] H[j]).

    h: [n, rows, d]; w: [n, n]; g: [n].
    """
    p = sinkhorn_ref(w, iters)
    m = jnp.einsum("ji,jrd->ird", p, h)
    inv = 1.0 / jnp.sqrt(jnp.mean(m * m, axis=-1, keepdims=True) + EPS)
    return h + g[:, None, None] * m * inv


def mhc_post_grad_ref(h, w, g, dy, iters=5):
    """VJP of mhc_post w.r.t. h, with stop-gradient through Sinkhorn."""
    p = sinkhorn_ref(w, iters)
    m = jnp.einsum("ji,jrd->ird", p, h)
    d = h.shape[-1]
    inv = 1.0 / jnp.sqrt(jnp.mean(m * m, axis=-1, keepdims=True) + EPS)
    dot = jnp.sum(dy * m, axis=-1, keepdims=True)
    dm = g[:, None, None] * (inv * dy - m * (inv**3) / d * dot)
    return dy + jnp.einsum("ji,ird->jrd", p, dm)
