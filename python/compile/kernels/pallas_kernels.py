"""Layer-1 Pallas kernels — the compute hot-spots, written with the same
staged tiling structure the AscendCraft DSL expresses (DESIGN.md
§Hardware-Adaptation):

* Unified Buffer (Ascend) maps to VMEM (TPU): every kernel stages blocks
  into VMEM via `BlockSpec` and keeps the per-step footprint well under
  16 MiB;
* the DSL's copyin/compute/copyout staging becomes Pallas grid steps —
  the grid pipeline overlaps HBM<->VMEM copies with compute the way TQue
  double buffering does on Ascend;
* MXU-friendly tiles: trailing dims stay multiples of 128, row blocks
  multiples of 8.

All kernels run `interpret=True`: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO so the
surrounding jit lowers into a single artifact the Rust runtime loads
(see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5

# Row-block size: 8 rows per grid step (8 x 2048 f32 = 64 KiB in VMEM,
# comfortably inside the ~16 MiB budget with double buffering).
ROW_BLOCK = 8


def _softmax_kernel(x_ref, o_ref, *, col_tile: int):
    """Figure-2-style tiled softmax: three passes over column tiles.

    Pass 1 computes the running row max, pass 2 the sum of exp(x - max),
    pass 3 normalizes — the same 3-pass dataflow the DSL example encodes,
    with `fori_loop` playing the role of the DSL's tile loop.
    """
    rows, cols = x_ref.shape
    n_tiles = cols // col_tile

    def pass1(t, row_max):
        tile = x_ref[:, pl.dslice(t * col_tile, col_tile)]
        return jnp.maximum(row_max, jnp.max(tile, axis=-1))

    row_max = jax.lax.fori_loop(0, n_tiles, pass1, jnp.full((rows,), -jnp.inf, x_ref.dtype))

    def pass2(t, row_sum):
        tile = x_ref[:, pl.dslice(t * col_tile, col_tile)]
        return row_sum + jnp.sum(jnp.exp(tile - row_max[:, None]), axis=-1)

    row_sum = jax.lax.fori_loop(0, n_tiles, pass2, jnp.zeros((rows,), x_ref.dtype))

    def pass3(t, _):
        tile = x_ref[:, pl.dslice(t * col_tile, col_tile)]
        o_ref[:, pl.dslice(t * col_tile, col_tile)] = (
            jnp.exp(tile - row_max[:, None]) / row_sum[:, None]
        )
        return 0

    jax.lax.fori_loop(0, n_tiles, pass3, 0)


def softmax(x, col_tile: int = 1024):
    """Tiled softmax over the last axis of a 2D array."""
    rows, cols = x.shape
    col_tile = min(col_tile, cols)
    assert cols % col_tile == 0, "column tile must divide cols"
    block_rows = ROW_BLOCK if rows % ROW_BLOCK == 0 else 1
    return pl.pallas_call(
        functools.partial(_softmax_kernel, col_tile=col_tile),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        interpret=True,
    )(x)


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref, *, lr, b1, b2, eps):
    """Fused Adam step over one 1D tile (the optimizer-category fusion)."""
    g = g_ref[...]
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    mo_ref[...] = m_new
    vo_ref[...] = v_new
    po_ref[...] = p_ref[...] - lr * m_new / (jnp.sqrt(v_new) + eps)


def adam_step(param, grad, m, v, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, tile=65536):
    """Fused Adam update over flat parameter vectors."""
    (n,) = param.shape
    tile = min(tile, n)
    assert n % tile == 0
    shape = jax.ShapeDtypeStruct(param.shape, param.dtype)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps),
        out_shape=(shape, shape, shape),
        grid=(n // tile,),
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec, spec),
        interpret=True,
    )(param, grad, m, v)


def _mhc_post_kernel(h_ref, p_ref, g_ref, y_ref):
    """Fused mHC post-merge over a row block of all streams.

    Mirrors the 'optimized' AscendC variant: each grid step loads one row
    block of every stream once, mixes with the doubly-stochastic P, RMS
    gates and adds the residual.
    """
    h = h_ref[...]  # [n, block_rows, d]
    p = p_ref[...]  # [n, n]
    g = g_ref[...]  # [n]
    m = jnp.einsum("ji,jrd->ird", p, h)
    inv = 1.0 / jnp.sqrt(jnp.mean(m * m, axis=-1, keepdims=True) + EPS)
    y_ref[...] = h + g[:, None, None] * m * inv


def mhc_post(h, w, g, iters: int = 5):
    """mHC post-merge; Sinkhorn projection runs at the JAX level (it is a
    4x4 computation), the heavy mixing/gating runs in the Pallas kernel."""
    from .ref import sinkhorn_ref

    n, rows, d = h.shape
    p = sinkhorn_ref(w, iters)
    block_rows = ROW_BLOCK if rows % ROW_BLOCK == 0 else 1
    return pl.pallas_call(
        _mhc_post_kernel,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((n, block_rows, d), lambda i: (0, i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, block_rows, d), lambda i: (0, i, 0)),
        interpret=True,
    )(h, p, g)


def _mhc_post_grad_kernel(h_ref, p_ref, g_ref, dy_ref, dh_ref):
    """Fused mHC post-merge VJP over a row block (optimized variant)."""
    h = h_ref[...]
    p = p_ref[...]
    g = g_ref[...]
    dy = dy_ref[...]
    d = h.shape[-1]
    m = jnp.einsum("ji,jrd->ird", p, h)
    inv = 1.0 / jnp.sqrt(jnp.mean(m * m, axis=-1, keepdims=True) + EPS)
    dot = jnp.sum(dy * m, axis=-1, keepdims=True)
    dm = g[:, None, None] * (inv * dy - m * (inv**3) / d * dot)
    dh_ref[...] = dy + jnp.einsum("ji,ird->jrd", p, dm)


def mhc_post_grad(h, w, g, dy, iters: int = 5):
    from .ref import sinkhorn_ref

    n, rows, d = h.shape
    p = sinkhorn_ref(w, iters)
    block_rows = ROW_BLOCK if rows % ROW_BLOCK == 0 else 1
    return pl.pallas_call(
        _mhc_post_grad_kernel,
        out_shape=jax.ShapeDtypeStruct(h.shape, h.dtype),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((n, block_rows, d), lambda i: (0, i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_rows, d), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_rows, d), lambda i: (0, i, 0)),
        interpret=True,
    )(h, p, g, dy)
