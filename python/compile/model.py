"""Layer-2 JAX reference library: the golden-oracle implementations of the
benchmark operators, AOT-lowered by aot.py into `artifacts/*.hlo.txt` for
the Rust runtime.

Each entry is (function, example-argument shapes matching the Rust task
specs). Every entry is pure jnp/lax: the Rust side executes the lowered
HLO text with its own self-contained interpreter (`rust/src/runtime/hlo`).
The supported op set is specified in `docs/HLO_SUBSET.md` — dense
arithmetic (add/subtract/multiply/divide/maximum/minimum/exponential/log/
tanh/sqrt/rsqrt/power/negate/abs/constant/broadcast/reshape/transpose/
reduce/reduce-window/dot/select/compare/convert/tuple), `iota`,
`dynamic-slice`, integer dtypes (s32/s64), and structured `while` loops
over a tuple-shaped carried state (how `lax.fori_loop` lowers) with
`get-tuple-element`. Still out of scope: `conditional`, variadic reduce
(so `jnp.argmax` must be spelled via iota + where + min-reduce, see
`argmax_rows`), `dynamic-update-slice` (so no `lax.scan` carrying
per-step outputs), gather/scatter, and anything routed through
`pallas_call`. The Pallas kernels in `kernels/pallas_kernels.py` are
still checked against these references by pytest; aot.py lowers the
references themselves. Python runs only at build time — the Rust binary
never imports any of this.
"""

import jax
import jax.numpy as jnp

from .kernels import ref as kref


# --------------------------------------------------------------- operators
# Shapes mirror rust/src/bench_suite/tasks.rs and rust/src/mhc.

EW = (1024, 4096)
ROWS = (512, 2048)
# Oracle-fixture shape for the mHC kernels: same structure as the Rust case
# study (MhcDims) but sized so the HLO interpreter cross-check stays fast in
# debug test builds. rust/tests/golden_oracle.rs uses these dims verbatim.
MHC = (4, 256, 512)


def relu(x):
    return (jnp.maximum(x, 0.0),)


def gelu(x):
    inner = 0.7978845608 * (x + 0.044715 * x * x * x)
    return (0.5 * x * (1.0 + jnp.tanh(inner)),)


def sigmoid(x):
    return (1.0 / (1.0 + jnp.exp(-x)),)


def silu(x):
    return (x * (1.0 / (1.0 + jnp.exp(-x))),)


def tanh_act(x):
    return (jnp.tanh(x),)


def leaky_relu(x):
    return (jnp.where(x >= 0.0, x, 0.01 * x),)


def softmax(x):
    return (kref.softmax_ref(x),)


def log_softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    s = jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True)
    return ((x - m) - jnp.log(s),)


def layernorm(x, gamma, beta):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return ((x - mean) / jnp.sqrt(var + 1e-5) * gamma + beta,)


def rmsnorm(x, gamma):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x / jnp.sqrt(ms + 1e-5) * gamma,)


def adam(param, grad, m, v):
    return kref.adam_ref(param, grad, m, v)


def mse_loss(pred, target):
    return (jnp.mean((pred - target) ** 2, keepdims=True).reshape(1),)


def cumsum(x):
    return (jnp.cumsum(x, axis=-1),)


def logsumexp(x):
    m = jnp.max(x, axis=-1)
    return (m + jnp.log(jnp.sum(jnp.exp(x - m[:, None]), axis=-1)),)


def sum_dim(x):
    return (jnp.sum(x, axis=-1),)


def huber_loss(pred, target):
    # matches the Rust reference: d < 1.0 -> 0.5*d*d, else d - 0.5
    d = jnp.abs(pred - target)
    ew = jnp.where(d < 1.0, 0.5 * d * d, d - 0.5)
    return (jnp.mean(ew).reshape(1),)


def maxpool2d(x):
    # [batch, h, w], window 3, stride 3, VALID — lowers to reduce-window,
    # exercising the interpreter's generic windowed-reduction path
    return (jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3), (1, 3, 3), "VALID"),)


def avgpool2d_pad(x):
    # [batch, h, w], window 3, stride 2, symmetric pad 1, divide-by-count
    # (count excludes padding, matching torch's count_include_pad=False):
    # two reduce-windows (sum over x, sum over ones) and a divide — the
    # lowering that keeps padded average pooling inside the interpreter's
    # op set without variadic reduce-window
    win, stride = (1, 3, 3), (1, 2, 2)
    pad = ((0, 0), (1, 1), (1, 1))
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add, win, stride, pad)
    cnt = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add, win, stride, pad)
    return (s / cnt,)


def argmax_rows(x):
    # first index of each row's max, as s32 — spelled via iota + where +
    # min-reduce because jnp.argmax lowers to a variadic reduce (outside
    # the interpreter's op set); exercises iota, s32 select/reduce, and
    # integer constants end-to-end
    m = jnp.max(x, axis=-1, keepdims=True)
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    big = jnp.full(x.shape, x.shape[-1], dtype=jnp.int32)
    first = jnp.min(jnp.where(x == m, idx, big), axis=-1)
    return (first,)


def window_sum(x):
    # sliding-window sum of 4 shifted column slices via lax.fori_loop +
    # lax.dynamic_slice — lowers to a `while` loop (tuple carried state,
    # get-tuple-element, a tuple-returning call) around `dynamic-slice`,
    # exercising the interpreter's structured-control-flow subset
    rows, cols = x.shape
    w = 4
    def body(i, acc):
        return acc + jax.lax.dynamic_slice(x, (0, i), (rows, cols - w + 1))
    out = jax.lax.fori_loop(0, w, body, jnp.zeros((rows, cols - w + 1), jnp.float32))
    return (out,)


def mhc_post(h, w, g):
    return (kref.mhc_post_ref(h, w, g),)


def mhc_post_grad(h, w, g, dy):
    return (kref.mhc_post_grad_ref(h, w, g, dy),)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (fn, example args). This is the artifact manifest.
OPS = {
    "relu": (relu, [_f32(*EW)]),
    "gelu": (gelu, [_f32(*EW)]),
    "sigmoid": (sigmoid, [_f32(*EW)]),
    "silu": (silu, [_f32(*EW)]),
    "tanh_act": (tanh_act, [_f32(*EW)]),
    "leaky_relu": (leaky_relu, [_f32(*EW)]),
    "softmax": (softmax, [_f32(*ROWS)]),
    "log_softmax": (log_softmax, [_f32(*ROWS)]),
    "layernorm": (layernorm, [_f32(*ROWS), _f32(ROWS[1]), _f32(ROWS[1])]),
    "rmsnorm": (rmsnorm, [_f32(*ROWS), _f32(ROWS[1])]),
    "adam": (adam, [_f32(4 * 1024 * 1024)] * 4),
    "mse_loss": (mse_loss, [_f32(*EW), _f32(*EW)]),
    "huber_loss": (huber_loss, [_f32(*EW), _f32(*EW)]),
    "maxpool2d": (maxpool2d, [_f32(64, 96, 96)]),
    "avgpool2d_pad": (avgpool2d_pad, [_f32(8, 32, 32)]),
    "argmax_rows": (argmax_rows, [_f32(64, 128)]),
    "window_sum": (window_sum, [_f32(128, 256)]),
    "cumsum": (cumsum, [_f32(512, 2048)]),
    "logsumexp": (logsumexp, [_f32(512, 2048)]),
    "sum_dim": (sum_dim, [_f32(1024, 4096)]),
    "mhc_post": (mhc_post, [_f32(*MHC), _f32(4, 4), _f32(4)]),
    "mhc_post_grad": (mhc_post_grad, [_f32(*MHC), _f32(4, 4), _f32(4), _f32(*MHC)]),
}

# re-export the kernel oracles for the test-suite's convenience
softmax_ref = kref.softmax_ref
adam_ref = kref.adam_ref
mhc_post_ref = kref.mhc_post_ref
mhc_post_grad_ref = kref.mhc_post_grad_ref
