"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

Usage (from python/):  python -m compile.aot [--out-dir ../artifacts] [ops...]

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True; the
Rust side unwraps the tuple. See /opt/xla-example/README.md.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import OPS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(name: str, out_dir: str) -> str:
    fn, args = OPS[name]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("ops", nargs="*", help="ops to build (default: all)")
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)
    names = ns.ops or list(OPS)
    unknown = [n for n in names if n not in OPS]
    if unknown:
        print(f"unknown ops: {unknown}; known: {sorted(OPS)}", file=sys.stderr)
        return 1
    for name in names:
        path = build(name, ns.out_dir)
        size = os.path.getsize(path)
        print(f"  wrote {path} ({size} bytes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
