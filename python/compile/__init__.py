"""Build-time compile path (never imported by the runtime)."""
